"""Distributed AÇAI: the paper's retrieval/caching step at pod scale.

At production scale the catalog (10^8 x d embeddings) and the fractional
cache state y live SHARDED over the `model` mesh axis; the request batch is
data-parallel.  One serve+update step per request batch:

  1. every chip scans its catalog shard with the fused distance+top-k
     kernel (Pallas `topk_l2` on TPU, the chunked XLA oracle elsewhere, or
     the sharded-IVF probe) and takes a local top-C
                                          -> compute-bound, no comms
  2. ONE all-gather over `model` of a packed per-shard candidate payload
     [dist, bitcast(global id), y, x] + a per-section top-C re-merge.
     Because a shard only ever proposes its own rows, it attaches the
     y/x state those rows will need right in the payload — the separate
     masked-psum state gathers of the first sharded version collapse
     into the merge itself (DESIGN.md §15)
  3. per-request gain/subgradient on the merged candidates (Eq. 55)
  4. subgradients routed to the owning y-shards: one packed
     [g, bitcast(id)] all_gather over `data` + local mask — skipped
     entirely on size-1 batch axes, where every shard already holds the
     full request batch
  5. OMA multiplicative update + DISTRIBUTED capped-simplex projection:
     per-shard top-A heads and exact tail sum packed as (A + 1,) scalars,
     ONE all-gather, the global water-filling scale solved redundantly on
     every shard — the O(N log N) sort of Sec. IV-F becomes
     O(N/P log A) + an O(A.P) scalar exchange.

Per-step collective budget (pinned by tests/test_collectives.py and
reported by `collectives_per_step`): the exact sharded step spends 2
all-gathers on a 1-device data axis (3 with data-parallel requests); the
IVF step spends one more because its remote merge is issued before the
cached-row scan so XLA can overlap the exchange with local compute.

The serve answer (global ids of the k cheapest augmented copies) comes out
of the same merged candidate set.  `make_retrieval_step` is the
paper-representative roofline cell (`acai-retrieval`) lowered by the
dry-run; `make_replay_sharded` is the serving-stack twin of
`repro.core.policy.make_replay_batched` — same mini-batch OMA semantics,
state carried as (y, x, t, key), bit-consistent with the batched replay on
a 1-device mesh (see DESIGN.md §7).  `make_mutable_step_sharded` is the
churn twin: catalog slab + liveness mask as runtime arguments, mutations
routed to the owning shard by global-id arithmetic (`route_ids_by_owner`,
`sharded_slab_append`), the projection run over the live mask — bitwise
`make_mutable_step` + exact candidates on a 1-device mesh (DESIGN.md §15).

All shard_map usage goes through `repro.compat` so the module lowers on
every supported jax version.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import gain as gain_lib
from repro.core import mirror as mirror_maps
from repro.core import oma as oma_lib
from repro.core import policy as policy_lib
from repro.core.costs import BIG_COST, pairwise_dissimilarity
from repro.core.projection import _negentropy_scale_from_sorted
from repro.kernels import ops


def _axis_size(mesh, axes) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    total = 1
    for ax in ([axes] if isinstance(axes, str) else axes):
        total *= sizes[ax]
    return total


# ---------------------------------------------------------------------------
# Sharded IVF: per-shard coarse quantizer + inverted lists (local row ids)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardedIVF:
    """Per-shard IVF structures, stacked along the (sharded) row axis.

    centroids: (P * nlist, d)  — shard p owns rows [p*nlist, (p+1)*nlist)
    invlists:  (P * nlist, cap) int32 — ids are LOCAL row offsets into the
               owning catalog shard, -1 padded
    """

    centroids: jax.Array
    invlists: jax.Array
    nlist: int
    nprobe: int

    def __hash__(self):
        return id(self)

    def __eq__(self, other):
        return self is other


def build_sharded_ivf(catalog, n_shards: int, *, nlist: int = 32,
                      nprobe: int = 8, train_iters: int = 12,
                      seed: int = 0) -> ShardedIVF:
    """Train one IVF coarse quantizer per catalog shard.

    Each shard gets its own k-means over its rows — exactly what a chip
    would do at scale (the invlist table shards row-wise, DESIGN.md §4) —
    so the sharded retrieval step probes only shard-local lists.
    """
    from repro.index.ivf import build_invlists
    from repro.index.kmeans import kmeans

    catalog = jnp.asarray(catalog, jnp.float32)
    n = catalog.shape[0]
    assert n % n_shards == 0, (n, n_shards)
    n_shard = n // n_shards
    cents, tables = [], []
    for p in range(n_shards):
        shard = catalog[p * n_shard:(p + 1) * n_shard]
        key = jax.random.PRNGKey(seed + p)
        c, assign = kmeans(key, shard, nlist, train_iters)
        cents.append(np.asarray(c))
        tables.append(build_invlists(np.asarray(assign), nlist))
    cap = max(t.shape[1] for t in tables)
    tables = [np.pad(t, ((0, 0), (0, cap - t.shape[1])), constant_values=-1)
              for t in tables]
    return ShardedIVF(
        centroids=jnp.asarray(np.concatenate(cents, 0), jnp.float32),
        invlists=jnp.asarray(np.concatenate(tables, 0), jnp.int32),
        nlist=nlist, nprobe=nprobe)


def _check_ivf_matches_mesh(ivf: "ShardedIVF | None", n_model: int) -> None:
    """A ShardedIVF built for P shards only makes sense on a P-way model
    axis: the P(model, None) in_spec would otherwise silently hand each
    mesh shard centroid/invlist rows belonging to a different catalog
    sub-shard (local row ids reinterpreted against the wrong shard — wrong
    candidates, no shape error)."""
    if ivf is None:
        return
    built_for = ivf.centroids.shape[0] // ivf.nlist
    if built_for != n_model:
        raise ValueError(
            f"ShardedIVF was built for {built_for} shards "
            f"(centroids {ivf.centroids.shape}, nlist {ivf.nlist}) but the "
            f"mesh's model axis has {n_model} devices — rebuild with "
            f"build_sharded_ivf(catalog, {n_model}, ...)")


def _local_scan(requests, catalog, c: int, scan_chunk: int, ivf_shard):
    """Per-shard local top-c scan: (dists (b, c), local ids (b, c)).

    Three variants (DESIGN.md §7): paper-faithful full matrix
    (scan_chunk = 0, ivf = None), the fused kernel path (`ops.topk_l2_fused`
    — Pallas on TPU, chunked XLA oracle elsewhere), and the sharded-IVF
    probe that scans only this shard's probed inverted lists.  Underflowing
    slots (IVF only) come back as dist = +inf, id = -1.
    """
    if ivf_shard is not None:
        centroids, invlists, nprobe = ivf_shard
        dc = pairwise_dissimilarity(requests, centroids)
        _, probe = jax.lax.top_k(-dc, nprobe)                # (b, nprobe)
        cand = invlists[probe].reshape(requests.shape[0], -1)
        return ops.ivf_scan_auto(requests, catalog, cand, c)
    if scan_chunk:
        return ops.topk_l2_fused(requests, catalog, c, chunk=scan_chunk)
    d2 = pairwise_dissimilarity(requests, catalog)
    neg, ids = jax.lax.top_k(-d2, c)
    return -neg, ids


# ---------------------------------------------------------------------------
# Fused-collective building blocks (DESIGN.md §15)
# ---------------------------------------------------------------------------

def _ids_to_f32(ids: jax.Array) -> jax.Array:
    """Bit-preserving int32 -> float32 view so candidate ids can ride in
    the same packed all-gather payload as their float columns.  Only data
    movement (gather / concat / take_along_axis) ever touches the packed
    lane, so the bit pattern round-trips exactly."""
    return jax.lax.bitcast_convert_type(ids.astype(jnp.int32), jnp.float32)


def _f32_to_ids(f: jax.Array) -> jax.Array:
    return jax.lax.bitcast_convert_type(f, jnp.int32)


def _candidate_payload(d, loc, miss, off, n: int, y_shard, x_shard):
    """Pack one shard-local candidate section as [d, bitcast(gid), y, x].

    The proposing shard OWNS every row it proposes, so it attaches the
    y/x state the merged slab will need — the masked-psum state gathers
    of the first sharded version collapse into the merge exchange.  Miss
    slots (IVF underflow) become (dist = +inf, id = n, y = 0, x = 0),
    matching the old sentinel semantics (out-of-range state reads were 0).
    """
    n_shard = y_shard.shape[0]
    safe = jnp.clip(loc, 0, n_shard - 1)
    return jnp.stack([
        jnp.where(miss, jnp.inf, d),
        _ids_to_f32(jnp.where(miss, n, loc + off)),
        jnp.where(miss, 0.0, y_shard[safe]),
        jnp.where(miss, 0.0, x_shard[safe]),
    ], axis=-1)


def _packed_merge(payload, counts, n_model: int, model_axis):
    """ONE all-gather of the packed candidate payload over `model`, then a
    per-section re-top-k (steps 2 of the module docstring — the fused
    replacement for per-array candidate gathers + per-state psums).

    payload: (b, sum(counts), L) float32 — the shard's candidate sections
      laid out side by side, column 0 the ascending sort key (the
      dissimilarity), the remaining columns riding along (bitcast ids,
      attached y/x state).
    counts: per-section budgets; section i re-merges to its global
      top-counts[i] independently.

    Returns one (dists (b, c), [other columns (b, c) ...]) per section.
    At P = 1 the gather is the identity and top_k over an already sorted
    section is order-preserving (stable ties) — bitwise a no-op.
    """
    b, ctot, ncol = payload.shape
    g = jax.lax.all_gather(payload, model_axis, axis=1, tiled=True)
    g = g.reshape(b, n_model, ctot, ncol)
    outs = []
    off = 0
    for c in counts:
        sec = g[:, :, off:off + c].reshape(b, n_model * c, ncol)
        negm, pos = jax.lax.top_k(-sec[..., 0], c)
        cols = [jnp.take_along_axis(sec[..., j], pos, axis=1)
                for j in range(1, ncol)]
        outs.append((-negm, cols))
        off += c
    return outs


def _route_subgradients(g_cand, ids, valid, off, n_shard: int, batch_axes,
                        n_batch: int, denom: float = 1.0):
    """Scatter-add per-request candidate subgradients into this shard's
    (n_shard,) y-slice (step 4 of the module docstring).

    The data-parallel exchange is ONE packed [g, bitcast(id)] all-gather
    over the batch axes: invalid candidate slots fold in by rewriting
    their id to -1 (owned by no shard) before packing, so the separate
    validity-mask gather of the first sharded version disappears.  On
    size-1 batch axes (`n_batch == 1`, known statically from the mesh)
    the exchange is skipped entirely — every shard already holds the full
    request batch.  `denom` is the mini-batch averaging divisor."""
    ids_eff = jnp.where(valid, ids, -1) if valid is not None else ids
    if n_batch > 1:
        packed = jnp.stack([g_cand, _ids_to_f32(ids_eff)], axis=-1)
        packed = jax.lax.all_gather(packed, batch_axes, axis=0, tiled=True)
        g_all, ids_all = packed[..., 0], _f32_to_ids(packed[..., 1])
    else:
        g_all, ids_all = g_cand, ids_eff
    mine = (ids_all >= off) & (ids_all < off + n_shard)
    lidx = jnp.clip(ids_all - off, 0, n_shard - 1)
    val = jnp.where(mine, g_all, 0.0).reshape(-1)
    if denom != 1.0:
        val = val / denom
    return jnp.zeros((n_shard,), g_cand.dtype).at[lidx.reshape(-1)].add(val)


def _distributed_projection(z, h, top_a: int, n_model: int, model_axis):
    """Distributed negentropy Bregman projection (Sec. IV-F water-filling).

    Per shard: top-A heads + exact tail sum (scatter-zero, no total-minus-
    top cancellation), packed as ONE (A + 1,) array so the whole exchange
    is a single all-gather of P·(A + 1) scalars — the first sharded
    version spent a heads all-gather plus a separate tail psum.  The
    global scale s is then solved redundantly on every shard from the same
    sorted head array — bitwise identical across shards — and applied
    locally.  At P = 1 this IS `capped_simplex_negentropy_topk`.

    Churn safety (DESIGN.md §15): dead rows must carry z = 0 — the mutable
    caller masks them — so a shard whose live count has fallen below A
    merely pads its head section with zeros, which the water-filling scan
    sorts to the tail and ignores; an all-tombstoned shard contributes
    nothing and the scale stays finite.  If no feasible water level exists
    at all (degenerate z after heavy removal) the scale falls back to 1
    instead of garbage — same guard as the single-device top-A projection.
    """
    z = jnp.maximum(z, 0.0)
    ztop, idx = jax.lax.top_k(z, top_a)
    tail = jnp.sum(z.at[idx].set(0.0))
    packed = jax.lax.all_gather(
        jnp.concatenate([ztop, tail[None]]), model_axis, tiled=True)
    packed = packed.reshape(n_model, top_a + 1)
    heads = packed[:, :top_a].reshape(-1)
    tails = jnp.sum(packed[:, top_a])
    if n_model > 1:
        heads = jnp.sort(heads)[::-1]
    s, ok = _negentropy_scale_from_sorted(heads, tails, h)
    s = jnp.where(ok, s, 1.0)
    return jnp.minimum(1.0, z * s)


# ---------------------------------------------------------------------------
# Collective accounting: the comm budget as a testable number
# ---------------------------------------------------------------------------

_COLLECTIVE_PREFIXES = ("psum", "all_gather", "all_to_all", "ppermute",
                       "reduce_scatter")


def _count_collectives(jaxpr, counts: dict) -> None:
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        for pref in _COLLECTIVE_PREFIXES:
            if name.startswith(pref):
                counts[name] = counts.get(name, 0) + 1
                break
        for v in eqn.params.values():
            for item in (v if isinstance(v, (list, tuple)) else (v,)):
                if isinstance(item, jax.core.ClosedJaxpr):
                    _count_collectives(item.jaxpr, counts)
                elif isinstance(item, jax.core.Jaxpr):
                    _count_collectives(item, counts)


def collectives_per_step(fn: Callable, *example_args, **example_kwargs):
    """Count the cross-device collectives one call of `fn` lowers to.

    Traces `fn` with `jax.make_jaxpr` and walks the program (descending
    into pjit / shard_map / scan sub-jaxprs), tallying primitives whose
    name starts with psum / all_gather / all_to_all / ppermute /
    reduce_scatter.  Returns (total, {primitive name: count}).

    This is static accounting on the traced program — no devices run — so
    `benchmarks/distributed_bench.py` can report the budget as a bench
    column and `tests/test_collectives.py` can pin it against refactors
    that would reintroduce per-candidate gathers, all without timing
    noise.  Per-step counts are per *traced call*; a scan over T steps
    reports one step's body count once (the walker counts program sites,
    not executions).
    """
    closed = jax.make_jaxpr(fn)(*example_args, **example_kwargs)
    counts: dict = {}
    _count_collectives(closed.jaxpr, counts)
    return sum(counts.values()), counts


# ---------------------------------------------------------------------------
# The roofline cell: stateless retrieval + OMA step on thresholded y
# ---------------------------------------------------------------------------

def make_retrieval_step(mesh, *, n_shard: int, d: int, c: int, k: int,
                        c_f: float, h: int, eta: float, top_a: int,
                        batch_axes=("data",), model_axis: str = "model",
                        scan_chunk: int = 0, ivf: ShardedIVF | None = None):
    """Returns step(catalog_shard, y, requests) -> (y_new, answer, metrics)
    wrapped in shard_map over `mesh`.

    catalog: (N, d) sharded P(model, None);  y: (N,) sharded P(model);
    requests: (B, d) sharded P(batch_axes, None).
    scan_chunk > 0 routes the local scan through the fused kernels
    (`ops.topk_l2_fused`: Pallas l2_topk on TPU, chunked XLA oracle
    elsewhere — memory-roofline optimization; 0 = paper-faithful full
    matrix).  `ivf` switches each shard to probing only its own inverted
    lists (`ops.ivf_scan_topk` / oracle) — the approximate-index serving
    configuration of Sec. IV-B at pod scale.

    The answer is the (B, k) global object ids of the k cheapest augmented
    copies per request (Eq. 2 on the merged candidates); -1 marks answer
    slots a starved IVF probe could not fill with a real candidate.
    """
    n_model = _axis_size(mesh, model_axis)
    n_batch = _axis_size(mesh, batch_axes)
    n = n_shard * n_model
    _check_ivf_matches_mesh(ivf, n_model)

    def step(catalog, y, requests, *ivf_args):
        # ---- 1. local distance scan + top-C (per shard) -----------------
        ivf_shard = (ivf_args[0], ivf_args[1], ivf.nprobe) if ivf else None
        loc_d, loc_ids = _local_scan(requests, catalog, c, scan_chunk,
                                     ivf_shard)
        my_shard = jax.lax.axis_index(model_axis)
        off = my_shard * n_shard

        # ---- 2. ONE packed merge over `model`: [d, gid, y] ---------------
        # (x doesn't exist in the retrieval cell — serving thresholds y)
        payload = _candidate_payload(loc_d, loc_ids, loc_ids < 0, off, n,
                                     y, y)[..., :3]
        (cand_d, (idf, y_cand)), = _packed_merge(payload, (c,), n_model,
                                                 model_axis)
        cand_ids = _f32_to_ids(idf)
        cand_d = jnp.where(jnp.isfinite(cand_d), cand_d, BIG_COST)

        # ---- 3. serve + subgradient (Eq. 2 / Eq. 55) ---------------------
        serve = jax.vmap(lambda dd, xx: gain_lib.serve(dd, xx, k, c_f))(
            cand_d, (y_cand > 0.5).astype(cand_d.dtype))
        _, g_cand = jax.vmap(
            lambda dd, yy: gain_lib.gain_and_subgradient(dd, yy, k, c_f))(
            cand_d, y_cand)
        answers = jnp.take_along_axis(cand_ids, serve.answer_ids, axis=1)
        # IVF underflow can leave < k real candidates; those answer slots
        # carry the out-of-range sentinel — surface them as id = -1 (the
        # kernels' underflow convention) rather than a clamping-prone n.
        answers = jnp.where(answers < n, answers, -1)

        # ---- 4. route subgradients to owning shards ----------------------
        g_shard = _route_subgradients(g_cand, cand_ids, None, off, n_shard,
                                      batch_axes, n_batch)

        # ---- 5. OMA + distributed projection -----------------------------
        z = mirror_maps.dual_ascent_step(y, g_shard, eta,
                                         mirror_maps.NEGENTROPY)
        y_new = jnp.clip(
            _distributed_projection(z, float(h), top_a, n_model, model_axis),
            1e-12, 1.0)

        metrics = {
            "gain": jax.lax.pmean(jnp.mean(serve.gain), batch_axes),
            "served_local": jax.lax.pmean(
                jnp.mean(jnp.sum(serve.from_cache, axis=1).astype(jnp.float32)),
                batch_axes),
        }
        return y_new, answers, metrics

    in_specs = [P(model_axis, None), P(model_axis), P(batch_axes, None)]
    if ivf is not None:
        in_specs += [P(model_axis, None), P(model_axis, None)]
    mapped = shard_map(
        step, mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=(P(model_axis), P(batch_axes, None),
                   {"gain": P(), "served_local": P()}),
        check_vma=False,
    )
    if ivf is None:
        return mapped
    return lambda catalog, y, requests: mapped(
        catalog, y, requests, ivf.centroids, ivf.invlists)


def reference_step(catalog, y, requests, *, c, k, c_f, h, eta, top_a):
    """Single-device oracle with identical semantics (for tests)."""
    from repro.core import projection

    d2 = pairwise_dissimilarity(requests, catalog)
    neg, ids = jax.lax.top_k(-d2, c)
    cand_d = -neg
    y_cand = y[ids]
    serve = jax.vmap(lambda dd, xx: gain_lib.serve(dd, xx, k, c_f))(
        cand_d, (y_cand > 0.5).astype(cand_d.dtype))
    _, g_cand = jax.vmap(
        lambda dd, yy: gain_lib.gain_and_subgradient(dd, yy, k, c_f))(
        cand_d, y_cand)
    g = jnp.zeros_like(y).at[ids.reshape(-1)].add(g_cand.reshape(-1))
    z = y * jnp.exp(jnp.clip(eta * g, -60.0, 60.0))
    y_new = projection.capped_simplex_negentropy_topk(z, h, top_a)
    answers = jnp.take_along_axis(ids, serve.answer_ids, axis=1)
    return jnp.clip(y_new, 1e-12, 1.0), answers


# ---------------------------------------------------------------------------
# The serving twin: sharded make_step_batched / make_replay_batched
# ---------------------------------------------------------------------------

def make_step_sharded(
    cfg: policy_lib.AcaiConfig, mesh, catalog: jax.Array, batch: int, *,
    eta_scale: float | None = None, model_axis: str = "model",
    batch_axes=("data",), scan_chunk: int = 0,
    ivf: ShardedIVF | None = None, top_a: int | None = None,
) -> Callable:
    """Sharded mini-batch step: (CacheState, requests (B, d)) ->
    (CacheState', StepMetrics (B,)) — the multi-device twin of
    `policy.make_step_batched` + `exact_candidate_fn_batched`.

    The candidate scan (per-shard fused top-k + ONE packed top-C merge
    carrying ids and y/x state in the same exchange), serve/gain/
    subgradient, and the OMA + water-filling projection all run under
    shard_map over `mesh` (catalog/y/x sharded P(model), requests
    P(batch_axes)); rounding and metric assembly reuse the policy-layer
    code on the (small) merged state outside the map.

    Per-step collectives (pinned by tests/test_collectives.py): 2 on a
    (1, P) serving mesh — the merge gather and the projection gather —
    plus 1 subgradient gather when the batch axes are real (> 1 device).
    The IVF/scan_chunk path spends one extra merge gather, issued before
    the cached-row scan so the exchange overlaps local compute.

    Bit-consistency contract (pinned by tests/test_distributed_acai.py):
    on a 1-device mesh with `scan_chunk = 0`, `ivf = None` and
    `cfg.oma.projection_topk == top_a`, every carried state and metric is
    bitwise identical to `make_step_batched` with the exact candidate
    generator.  `top_a` defaults to `cfg.oma.projection_topk` (or 2h + 64)
    per shard — headroom for the distributed projection, Sec. IV-F.

    Requires the negentropy mirror map (the distributed water-filling
    solves the negentropy scale; euclidean would need a different exchange).
    """
    if cfg.oma.mirror != mirror_maps.NEGENTROPY:
        raise NotImplementedError(
            "make_step_sharded requires the negentropy mirror map")
    n, d = catalog.shape
    n_model = _axis_size(mesh, model_axis)
    n_batch = _axis_size(mesh, batch_axes)
    if n % n_model:
        raise ValueError(
            f"catalog rows ({n}) must divide by the mesh's {model_axis} "
            f"axis ({n_model})")
    if batch % n_batch:
        raise ValueError(
            f"batch size {batch} must divide by the mesh's batch axes "
            f"{batch_axes} (total size {n_batch}); note serve_update "
            f"(B = 1) only exists on meshes with size-1 batch axes")
    _check_ivf_matches_mesh(ivf, n_model)
    n_shard = n // n_model
    a = min(n_shard, top_a or cfg.oma.projection_topk or 2 * cfg.h + 64)
    cfg_up = policy_lib.scaled_config(cfg, batch, eta_scale)

    def local(catalog_shard, y, x, rs, *ivf_args):
        my_shard = jax.lax.axis_index(model_axis)
        off = my_shard * n_shard
        b = rs.shape[0]

        # ---- candidates: per-shard scan + ONE packed top-C merge --------
        local_overflow = jnp.zeros((), jnp.int32)
        if scan_chunk == 0 and ivf is None:
            # paper-faithful / bit-consistent path: one (b, n_shard) GEMM
            # feeds both the remote top-k and the cached-row top-k, exactly
            # as exact_candidate_fn_batched does on the full catalog (no
            # cached-row gather bound, so nothing can truncate).  Both
            # candidate sections ship in a single payload gather.
            d_full = pairwise_dissimilarity(rs, catalog_shard)
            neg_r, loc_r = jax.lax.top_k(-d_full, cfg.c_remote)
            d_cached = jnp.where(x[None, :] > 0.5, d_full, jnp.inf)
            neg_l, loc_l = jax.lax.top_k(-d_cached, cfg.c_local)
            payload = jnp.concatenate([
                _candidate_payload(-neg_r, loc_r, jnp.zeros(neg_r.shape, bool),
                                   off, n, y, x),
                _candidate_payload(-neg_l, loc_l, jnp.zeros(neg_l.shape, bool),
                                   off, n, y, x)], axis=1)
            merged = _packed_merge(payload, (cfg.c_remote, cfg.c_local),
                                   n_model, model_axis)
        else:
            ivf_shard = ((ivf_args[0], ivf_args[1], ivf.nprobe)
                         if ivf else None)
            d_r, loc_r = _local_scan(rs, catalog_shard, cfg.c_remote,
                                     scan_chunk, ivf_shard)
            # the remote merge is issued FIRST, before any cached-row
            # work it doesn't depend on: XLA overlaps the exchange with
            # the gather + GEMM below (comm/compute overlap, DESIGN.md
            # §15) at the price of one extra collective vs the exact path.
            remote = _packed_merge(
                _candidate_payload(d_r, loc_r, loc_r < 0, off, n, y, x),
                (cfg.c_remote,), n_model, model_axis)[0]
            # cached rows: gather once per shard (static 2h + 64 bound,
            # same policy as index_candidate_fn_batched) + one small GEMM.
            cap = min(n_shard, 2 * cfg.h + 64)
            if cfg.debug:
                # same truncation-visibility contract as the single-device
                # step (StepMetrics.local_overflow): per-shard excess over
                # the static gather bound, summed over the model axis.
                occ = jnp.sum((x > 0.5).astype(jnp.int32))
                local_overflow = jax.lax.psum(
                    jnp.maximum(occ - cap, 0), model_axis)
            cached = jnp.nonzero(x > 0.5, size=cap, fill_value=-1)[0]
            cached_embs = catalog_shard[jnp.clip(cached, 0, n_shard - 1)]
            d_loc = pairwise_dissimilarity(rs, cached_embs)
            d_loc = jnp.where((cached >= 0)[None, :], d_loc, jnp.inf)
            neg_l, pos = jax.lax.top_k(-d_loc, cfg.c_local)
            loc_l = jnp.where(jnp.isfinite(neg_l), cached[pos], 0)
            local_m = _packed_merge(
                _candidate_payload(-neg_l, loc_l,
                                   jnp.zeros(neg_l.shape, bool), off, n,
                                   y, x),
                (cfg.c_local,), n_model, model_axis)[0]
            merged = [remote, local_m]

        (d_remote, cols_r), (d_local, cols_l) = merged
        ids = jnp.concatenate([_f32_to_ids(cols_r[0]),
                               _f32_to_ids(cols_l[0])], axis=1)   # (b, C)
        dcand = jnp.concatenate([d_remote, d_local], axis=1)
        y_at = jnp.concatenate([cols_r[1], cols_l[1]], axis=1)
        x_at = jnp.concatenate([cols_r[2], cols_l[2]], axis=1)

        # ---- slab assembly: exactly exact_candidate_fn_batched ----------
        valid = policy_lib.dedup_mask_batched(ids, n)
        cached_ok = jnp.concatenate(
            [jnp.ones((b, cfg.c_remote), bool),
             x_at[:, cfg.c_remote:] > 0.5], axis=1)
        valid = valid & cached_ok
        dcand = jnp.where(valid & jnp.isfinite(dcand), dcand, BIG_COST)

        # ---- serve + gain/subgradient (vs the same x_t / y_t) -----------
        x_cand = jnp.where(valid, x_at, 0.0)
        y_cand = jnp.where(valid, y_at, 0.0)
        served = gain_lib.serve_batch(dcand, x_cand, cfg.k, cfg.c_f)
        gain_frac, g_cand = gain_lib.gain_and_subgradient_batch(
            dcand, y_cand, cfg.k, cfg.c_f)

        # ---- route subgradients to owning y-shards ----------------------
        g_shard = _route_subgradients(g_cand, ids, valid, off, n_shard,
                                      batch_axes, n_batch,
                                      denom=float(batch))

        # ---- OMA + distributed water-filling projection -----------------
        z = mirror_maps.dual_ascent_step(y, g_shard, cfg_up.oma.eta,
                                         cfg.oma.mirror)
        y_new = jnp.clip(
            _distributed_projection(z, cfg.h, a, n_model, model_axis),
            oma_lib.Y_FLOOR, 1.0)

        served_local = jnp.sum(served.from_cache.astype(jnp.int32), axis=1)
        return (y_new, served.gain, gain_frac, served.cost, served_local,
                local_overflow)

    in_specs = [P(model_axis, None), P(model_axis), P(model_axis),
                P(batch_axes, None)]
    extra = ()
    if ivf is not None:
        in_specs += [P(model_axis, None), P(model_axis, None)]
        extra = (ivf.centroids, ivf.invlists)
    mapped = shard_map(
        local, mesh=mesh, in_specs=tuple(in_specs),
        # local_overflow is a model-axis psum (or a constant 0): identical
        # on every shard, hence replicated
        out_specs=(P(model_axis),) + (P(batch_axes),) * 4 + (P(),),
        check_vma=False,
    )

    def step(state: policy_lib.CacheState, rs: jax.Array):
        key, k_round = jax.random.split(state.key)
        y_new, gain_int, gain_frac, cost, served_local, overflow = mapped(
            catalog, state.y, state.x, rs, *extra)
        return policy_lib.finish_step_batched(
            cfg_up, state, key, k_round, batch, y_new, gain_int, gain_frac,
            cost, served_local, local_overflow=overflow)

    return step


def make_replay_sharded(
    cfg: policy_lib.AcaiConfig, mesh, catalog: jax.Array, batch: int,
    **kwargs,
) -> Callable:
    """Sharded mini-batched whole-trace replay — the multi-device twin of
    `policy.make_replay_batched` (same signature contract: (state,
    requests (T, d)) -> (state', StepMetrics (T,)), T divisible by batch).

    On a 1-device mesh with `cfg.oma.projection_topk == top_a` this is
    bit-consistent with `make_replay_batched` + exact candidates; on P
    shards the per-step communication is one packed candidate gather plus
    the (P·(A + 1)) projection scalars (DESIGN.md §15).
    """
    return policy_lib.make_replay_from_step(
        make_step_sharded(cfg, mesh, catalog, batch, **kwargs), batch)


# ---------------------------------------------------------------------------
# Sharded churn: the mutable-catalog serving mode at pod scale
# ---------------------------------------------------------------------------

def make_mutable_step_sharded(
    cfg: policy_lib.AcaiConfig, mesh, batch: int, *,
    eta_scale: float | None = None, model_axis: str = "model",
    batch_axes=("data",), top_a: int | None = None,
) -> Callable:
    """Sharded twin of the mutable-catalog serving mode (DESIGN.md §10/§15):
    jitted (state, requests (B, d), catalog (cap, d), alive (cap,)) ->
    (state', StepMetrics (B,)).

    The catalog slab and its liveness mask are RUNTIME arguments — exactly
    like `exact_mutable_candidates` — so online add/remove/compact change
    only array values at fixed capacity and never retrace; a capacity-
    doubling growth retraces once per doubling, same as the single-device
    path.  Per shard: the scan masks tombstoned rows to +inf, the merged
    slab uses the capacity sentinel for empty candidate slots, dead-row z
    mass is re-zeroed before the distributed projection (a shard whose
    live count has fallen below top-A — or to zero — contributes padded
    zero heads the water-filling ignores), and the post-projection alive
    mask keeps the Y_FLOOR clip from resurrecting removed rows
    (`apply_candidates_batched`'s invalidation invariant, shard-wise).

    Bit-consistency contract (pinned by tests/test_sharded_churn.py): on a
    1-device mesh with `cfg.oma.projection_topk == top_a`, state and every
    metric are bitwise `exact_mutable_candidates` + `make_mutable_step`,
    including under churn, capacity growth and compaction.

    Per-step collectives: the same 2 (serving mesh) / 3 (data-parallel)
    as the static exact step — mutability adds zero communication.
    """
    if cfg.oma.mirror != mirror_maps.NEGENTROPY:
        raise NotImplementedError(
            "make_mutable_step_sharded requires the negentropy mirror map")
    n_model = _axis_size(mesh, model_axis)
    n_batch = _axis_size(mesh, batch_axes)
    if batch % n_batch:
        raise ValueError(
            f"batch size {batch} must divide by the mesh's batch axes "
            f"{batch_axes} (total size {n_batch})")
    cfg_up = policy_lib.scaled_config(cfg, batch, eta_scale)

    def local(y, x, rs, cat_shard, alive_shard):
        my_shard = jax.lax.axis_index(model_axis)
        n_shard = cat_shard.shape[0]
        cap = n_shard * n_model
        off = my_shard * n_shard
        b = rs.shape[0]
        a = min(n_shard, top_a or cfg.oma.projection_topk or 2 * cfg.h + 64)

        # ---- candidates: exact_mutable_candidates, shard-wise -----------
        d_full = pairwise_dissimilarity(rs, cat_shard)
        d_full = jnp.where(alive_shard[None, :], d_full, jnp.inf)
        neg_r, loc_r = jax.lax.top_k(-d_full, cfg.c_remote)
        miss_r = ~jnp.isfinite(neg_r)     # fewer live rows than c_remote
        d_cached = jnp.where(x[None, :] > 0.5, d_full, jnp.inf)
        neg_l, loc_l = jax.lax.top_k(-d_cached, cfg.c_local)
        payload = jnp.concatenate([
            _candidate_payload(-neg_r, loc_r, miss_r, off, cap, y, x),
            _candidate_payload(-neg_l, loc_l, jnp.zeros(neg_l.shape, bool),
                               off, cap, y, x)], axis=1)
        (d_remote, cols_r), (d_local, cols_l) = _packed_merge(
            payload, (cfg.c_remote, cfg.c_local), n_model, model_axis)
        ids = jnp.concatenate([_f32_to_ids(cols_r[0]),
                               _f32_to_ids(cols_l[0])], axis=1)
        dcand = jnp.concatenate([d_remote, d_local], axis=1)
        y_at = jnp.concatenate([cols_r[1], cols_l[1]], axis=1)
        x_at = jnp.concatenate([cols_r[2], cols_l[2]], axis=1)

        valid = policy_lib.dedup_mask_batched(ids, cap)
        cached_ok = jnp.concatenate(
            [jnp.ones((b, cfg.c_remote), bool),
             x_at[:, cfg.c_remote:] > 0.5], axis=1)
        valid = valid & cached_ok
        dcand = jnp.where(valid, dcand, BIG_COST)

        # ---- serve + gain/subgradient -----------------------------------
        x_cand = jnp.where(valid, x_at, 0.0)
        y_cand = jnp.where(valid, y_at, 0.0)
        served = gain_lib.serve_batch(dcand, x_cand, cfg.k, cfg.c_f)
        gain_frac, g_cand = gain_lib.gain_and_subgradient_batch(
            dcand, y_cand, cfg.k, cfg.c_f)

        g_shard = _route_subgradients(g_cand, ids, valid, off, n_shard,
                                      batch_axes, n_batch,
                                      denom=float(batch))

        # ---- OMA + distributed projection over the live mask ------------
        z = mirror_maps.dual_ascent_step(y, g_shard, cfg_up.oma.eta,
                                         cfg.oma.mirror)
        # dead rows carry z = 0 by the invalidation invariant (y = 0 and
        # no routed mass); re-assert it so a shard tombstoned below top-A
        # pads the projection exchange with zeros instead of stale mass
        z = jnp.where(alive_shard, z, 0.0)
        y_new = jnp.clip(
            _distributed_projection(z, cfg.h, a, n_model, model_axis),
            oma_lib.Y_FLOOR, 1.0)
        y_new = jnp.where(alive_shard, y_new, 0.0)

        served_local = jnp.sum(served.from_cache.astype(jnp.int32), axis=1)
        return (y_new, served.gain, gain_frac, served.cost, served_local)

    mapped = shard_map(
        local, mesh=mesh,
        in_specs=(P(model_axis), P(model_axis), P(batch_axes, None),
                  P(model_axis, None), P(model_axis)),
        out_specs=(P(model_axis),) + (P(batch_axes),) * 4,
        check_vma=False,
    )

    @jax.jit
    def step(state: policy_lib.CacheState, rs, catalog, alive):
        key, k_round = jax.random.split(state.key)
        y_new, gain_int, gain_frac, cost, served_local = mapped(
            state.y, state.x, rs, catalog, alive)
        return policy_lib.finish_step_batched(
            cfg_up, state, key, k_round, batch, y_new, gain_int, gain_frac,
            cost, served_local)

    return step


# ---------------------------------------------------------------------------
# Owner-shard mutation routing: global-id arithmetic over contiguous shards
# ---------------------------------------------------------------------------

def owner_shard(ids, cap: int, n_model: int) -> np.ndarray:
    """Owning shard of each global slab row: shard p owns the contiguous
    block [p * cap / P, (p + 1) * cap / P) — pure arithmetic, no lookup
    table, so routing survives capacity growth and compaction as long as
    the capacity stays a multiple of the mesh (which the doubling schedule
    and the compaction round-up guarantee)."""
    if cap % n_model:
        raise ValueError(
            f"slab capacity {cap} must divide by the mesh's {n_model} "
            f"model shards")
    return np.asarray(ids, np.int64) // (cap // n_model)


def route_ids_by_owner(ids, cap: int, n_model: int):
    """Group a global-id mutation batch by owning shard.

    Returns [(shard, ids_subset np.int32), ...] in ascending shard order;
    each subset keeps the batch's original relative order, and the
    concatenation of all subsets is a permutation of the input (the
    round-trip property pinned by tests/test_sharded_churn.py).  At P = 1
    the single group IS the input — the single-device mutation path,
    bitwise."""
    ids = np.atleast_1d(np.asarray(ids, np.int32))
    own = owner_shard(ids, cap, n_model)
    return [(int(p), ids[own == p]) for p in np.unique(own)]


def sharded_slab_append(emb, valid, n_slots: int, vectors, n_model: int):
    """`repro.index.base.slab_append` with the write routed per owning
    shard (DESIGN.md §15): the appended rows [n_slots, n_slots + B) are
    split at shard-block boundaries (a batch can straddle two shards'
    contiguous blocks) and each run is written with its own donated
    `_slab_write` into the owner's slice.  Growth follows the same
    capacity-doubling schedule as the single-device path, rounded up to a
    multiple of the mesh so shard blocks never fracture (a no-op for the
    power-of-two capacities the doubling schedule produces on power-of-two
    meshes).  At P = 1 there is one run and this IS `slab_append` —
    bitwise, including the growth schedule.

    Returns (emb', valid', ids) with the `slab_append` contract:
    monotonic never-recycled ids = arange(n_slots, n_slots + B).
    """
    from repro.index.base import (_slab_write, bucket_width, grow_capacity,
                                  pad_rows, run_device)

    vec_np = np.atleast_2d(np.asarray(vectors, np.float32))
    b = vec_np.shape[0]
    cap = emb.shape[0]
    if cap % n_model:
        raise ValueError(
            f"slab capacity {cap} must divide by the mesh's {n_model} "
            f"model shards")
    while True:
        # split the append into per-shard-block runs, then check every
        # run's PADDED write window (the dynamic_update_slice clamp guard,
        # see slab_append) against capacity; growth moves the block
        # boundaries, so re-split until the layout is stable
        block = cap // n_model
        runs = []
        start = 0
        while start < b:
            row = n_slots + start
            run = min(b - start, (row // block + 1) * block - row)
            runs.append((row, run))
            start += run
        need = max(row + bucket_width(run) for row, run in runs)
        if need <= cap:
            break
        new_cap = grow_capacity(0, need, cap)
        new_cap += (-new_cap) % n_model
        emb = jnp.pad(emb, ((0, new_cap - cap), (0, 0)))
        valid = jnp.pad(valid, (0, new_cap - cap), constant_values=False)
        cap = new_cap
    for row, run in runs:
        lo = row - n_slots
        emb, valid = run_device(
            _slab_write, emb, valid, pad_rows(vec_np[lo:lo + run]),
            np.int32(row), np.int32(run))
    return emb, valid, np.arange(n_slots, n_slots + b, dtype=np.int32)
