"""Multi-step decode == full forward, for the cache-bearing arch families
(linear KV, MLA latent, SSM state, SWA ring) — the serving-path invariant
that matters for long generations."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import SMOKE_ARCHS
from repro.models import forward, init_cache, init_params

FAMILIES = ["qwen1.5-0.5b", "mamba2-130m", "mixtral-8x22b",
            "deepseek-v3-671b", "jamba-1.5-large-398b"]


@pytest.mark.parametrize("arch", FAMILIES)
def test_five_step_decode_matches_full_forward(arch):
    cfg = SMOKE_ARCHS[arch]
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    B, S, STEPS = 2, 16, 5
    toks = jax.random.randint(key, (B, S + STEPS), 0, cfg.vocab)

    full = forward(params, cfg, tokens=toks)
    cache = init_cache(cfg, B, S + STEPS + 4)
    out = forward(params, cfg, tokens=toks[:, :S], cache=cache, cache_len=0)
    worst = 0.0
    for j in range(STEPS):
        out = forward(params, cfg, tokens=toks[:, S + j:S + j + 1],
                      cache=out.cache, cache_len=S + j)
        a = np.array(full.logits[:, S + j])
        b = np.array(out.logits[:, 0])
        worst = max(worst, np.abs(a - b).max() / (np.abs(a).max() + 1e-9))
    assert worst < 3e-2, worst


def test_swa_ring_cache_long_decode():
    """Decode far past the window: ring cache must equal a full forward
    restricted to the window."""
    import dataclasses
    cfg = dataclasses.replace(SMOKE_ARCHS["mixtral-8x22b"], sliding_window=8)
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    B, TOTAL = 1, 40
    toks = jax.random.randint(key, (B, TOTAL), 0, cfg.vocab)
    full = forward(params, cfg, tokens=toks)
    # ring cache sized to the window (s_max > window would use linear path)
    cache = init_cache(cfg, B, cfg.sliding_window)
    out = forward(params, cfg, tokens=toks[:, :16], cache=cache, cache_len=0)
    worst = 0.0
    for j in range(16, TOTAL):
        out = forward(params, cfg, tokens=toks[:, j:j + 1], cache=out.cache,
                      cache_len=j)
        a = np.array(full.logits[:, j])
        b = np.array(out.logits[:, 0])
        worst = max(worst, np.abs(a - b).max() / (np.abs(a).max() + 1e-9))
    assert worst < 3e-2, worst
