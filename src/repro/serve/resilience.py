"""Resilient serving tier: deadlines, retries, hedging, circuit breaking,
and the graceful-degradation ladder (DESIGN.md §11).

The failure model (repro.serve.remote) only blocks *remote fetches*; the
approximate indexes and the embedding catalog are edge-local metadata, so
distances stay computable and the OMA ascent (Eq. 55) is fault-
independent.  That observation shapes the whole ladder — on a remote
failure the policy still knows exactly which cached object is closest:

1. retry — capped exponential backoff with deterministic jitter, up to
   `RetryConfig.max_retries` extra attempts inside the deadline budget;
2. hedge — an optional second request fired `hedge_ms` into a slow
   attempt, completion = first success (tail-latency insurance);
3. circuit-break — after `failure_threshold` consecutive failures the
   breaker opens and requests fail fast for `cooldown_requests`, then a
   half-open probe decides recovery (closed→open→half-open, with a
   decision log);
4. degrade — serve the best *local* candidates within
   `degrade_ceiling * c_f` dissimilarity, booking their true cost into
   `StepMetrics` (`degraded` counter); the OMA state keeps ascending and
   the physical cache `x` freezes only while the batch is fully failed
   (fetching needs the remote tier);
5. shed — only when nothing local is inside the ceiling (`shed`
   counter); NaN/corrupt payloads are detected (`remote.payload_ok`) and
   treated as failures, never handed to policy state.

Everything runs on a *virtual* clock fed by the remote backend's
deterministic latency schedule — `simulate_request` is a pure function
of `(remote, t, config)` modulo breaker state, so fault sweeps are
replayable bit-for-bit and a null fault schedule leaves the serving path
bitwise identical to `make_replay_batched` (pinned by
tests/test_resilience.py).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gain as gain_lib
from repro.core import oma as oma_lib
from repro.core import policy as acai
from repro.core import rounding as rounding_lib
from repro.core.policy import StepMetrics
from repro.serve.remote import (FaultSpec, FaultyRemote, OracleRemote,
                                RemoteBackend, payload_ok)
from repro.train.fault import StragglerMonitor

#: schedule index of an attempt's hedge twin — far outside any plausible
#: retry count, so hedge draws never collide with retry draws
HEDGE_ATTEMPT_OFFSET = 1 << 20


@dataclasses.dataclass(frozen=True)
class RetryConfig:
    """Per-attempt timeout + capped exponential backoff with jitter."""

    max_retries: int = 2            # extra attempts after the first
    backoff_ms: float = 10.0        # base delay before retry #1
    backoff_cap_ms: float = 100.0   # exponential growth cap
    jitter: float = 0.1             # uniform multiplicative jitter in [0, j]
    attempt_timeout_ms: Optional[float] = 100.0  # None = wait forever


@dataclasses.dataclass(frozen=True)
class BreakerConfig:
    """Circuit-breaker thresholds (request-count based: the serving loop
    has no wall clock, cooldown is measured in request indices)."""

    failure_threshold: int = 8      # consecutive failures before opening
    cooldown_requests: int = 64     # open duration before half-open
    half_open_probes: int = 1       # probes allowed through half-open


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """Everything the resilient serving path needs, in one knob."""

    deadline_ms: Optional[float] = 250.0  # per-request budget (None = off)
    retry: RetryConfig = dataclasses.field(default_factory=RetryConfig)
    hedge_ms: Optional[float] = None      # fire a hedge this far into an
    #                                       attempt (None = no hedging)
    breaker: BreakerConfig = dataclasses.field(default_factory=BreakerConfig)
    # degraded serve: local candidates within ceiling x the request's best
    # healthy-serve cost (nearest dissimilarity + c_f) are eligible; shed
    # past it (scale-free — see degraded_serve)
    degrade_ceiling: float = 2.0
    slow_fetch_factor: float = 3.0  # StragglerMonitor threshold on fetches
    seed: int = 0                   # backoff-jitter stream

    def __post_init__(self):
        if self.degrade_ceiling <= 0:
            raise ValueError(
                f"degrade_ceiling must be > 0: {self.degrade_ceiling}")


class RequestReport(NamedTuple):
    """What one request experienced at the remote tier (virtual time)."""

    ok: bool
    retries: int          # attempts beyond the first
    hedged: bool          # a hedge request fired
    deadline_miss: bool   # the budget was exceeded
    latency_ms: float     # virtual completion time
    failure_kind: str     # '' | 'error' | 'corrupt' | 'outage' |
    #                       'timeout' | 'deadline' | 'breaker_open'
    fast_failed: bool     # breaker open: not even attempted


class CircuitBreaker:
    """closed -> open -> half-open state machine with a decision log.

    `allow(t)` gates request `t` (False = fail fast), `record(t, ok)`
    feeds the outcome back.  Transitions are appended to `log` as
    `{"t", "from", "to", "reason"}` dicts — the decision log the bench
    reports and the tests pin."""

    def __init__(self, cfg: BreakerConfig = BreakerConfig()):
        self.cfg = cfg
        self.state = "closed"
        self.failures = 0           # consecutive, while closed
        self.opened_at = -1
        self.probes_left = 0
        self.log: List[dict] = []

    def _to(self, state: str, t: int, reason: str) -> None:
        self.log.append({"t": int(t), "from": self.state, "to": state,
                         "reason": reason})
        self.state = state

    def allow(self, t: int) -> bool:
        if self.state == "open":
            if t - self.opened_at >= self.cfg.cooldown_requests:
                self._to("half_open", t, "cooldown elapsed")
                self.probes_left = self.cfg.half_open_probes
            else:
                return False
        if self.state == "half_open":
            if self.probes_left <= 0:
                return False
            self.probes_left -= 1
        return True

    def record(self, t: int, ok: bool) -> None:
        if ok:
            if self.state == "half_open":
                self._to("closed", t, "probe succeeded")
            self.failures = 0
            return
        if self.state == "half_open":
            self.opened_at = t
            self._to("open", t, "probe failed")
        elif self.state == "closed":
            self.failures += 1
            if self.failures >= self.cfg.failure_threshold:
                self.opened_at = t
                self._to("open", t,
                         f"{self.failures} consecutive failures")

    @property
    def transitions(self) -> int:
        return len(self.log)


def _one_attempt(remote: RemoteBackend, t: int, attempt: int,
                 rc: RetryConfig) -> Tuple[bool, float, str]:
    """(success, virtual latency, failure kind) of a single attempt."""
    o = remote.outcome(t, attempt)
    tmo = rc.attempt_timeout_ms
    if o.kind == "ok":
        if tmo is not None and o.latency_ms > tmo:
            return False, tmo, "timeout"   # cancelled at the timeout
        return True, o.latency_ms, ""
    lat = o.latency_ms if tmo is None else min(o.latency_ms, tmo)
    return False, lat, o.kind


def _attempt_with_hedge(remote: RemoteBackend, t: int, attempt: int,
                        cfg: ResilienceConfig) -> Tuple[bool, float, str, bool]:
    """One attempt plus its optional hedge twin; completion = first
    success (min over the two virtual finish times)."""
    rc = cfg.retry
    ok1, lat1, kind1 = _one_attempt(remote, t, attempt, rc)
    if cfg.hedge_ms is None or lat1 <= cfg.hedge_ms:
        return ok1, lat1, kind1, False
    ok2, lat2, kind2 = _one_attempt(
        remote, t, attempt + HEDGE_ATTEMPT_OFFSET, rc)
    done2 = cfg.hedge_ms + lat2
    if ok1 and ok2:
        return True, min(lat1, done2), "", True
    if ok1:
        return True, lat1, "", True
    if ok2:
        return True, done2, "", True
    return False, max(lat1, done2), kind1, True


def _backoff_ms(rc: RetryConfig, seed: int, t: int, attempt: int) -> float:
    base = min(rc.backoff_ms * (2.0 ** attempt), rc.backoff_cap_ms)
    if rc.jitter <= 0:
        return base
    u = np.random.default_rng(
        np.random.SeedSequence((seed, int(t), int(attempt), 0xB0FF))).random()
    return base * (1.0 + rc.jitter * u)


def simulate_request(remote: RemoteBackend, t: int, cfg: ResilienceConfig,
                     breaker: Optional[CircuitBreaker] = None
                     ) -> RequestReport:
    """Run one request's remote interaction on the virtual clock.

    Pure given (remote schedule, t, cfg) modulo breaker state: the
    deterministic core the retry/hedge/deadline tests exercise.  A
    success that lands past the deadline is a *failure* (the user is
    gone) and books a deadline miss."""
    if breaker is not None and not breaker.allow(t):
        return RequestReport(False, 0, False, False, 0.0, "breaker_open",
                             True)
    rc = cfg.retry
    now, retries, hedged, kind, ok = 0.0, 0, False, "", False
    attempt = 0
    while True:
        ok_a, lat_a, kind_a, h = _attempt_with_hedge(remote, t, attempt, cfg)
        hedged = hedged or h
        now += lat_a
        if ok_a:
            ok = True
            break
        kind = kind_a
        if attempt >= rc.max_retries:
            break
        if cfg.deadline_ms is not None and now >= cfg.deadline_ms:
            break  # budget exhausted: no point starting another attempt
        now += _backoff_ms(rc, cfg.seed, t, attempt)
        if cfg.deadline_ms is not None and now >= cfg.deadline_ms:
            break
        attempt += 1
        retries += 1
    miss = cfg.deadline_ms is not None and now > cfg.deadline_ms
    if ok and miss:
        ok, kind = False, "deadline"  # a late answer is a failed answer
    if breaker is not None:
        breaker.record(t, ok)
    return RequestReport(ok, retries, hedged, miss, now, kind, False)


# ---------------------------------------------------------------------------
# Session bookkeeping shared by the AÇAI and baseline resilient paths
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ResilienceCounters:
    requests: int = 0
    remote_failures: int = 0
    retries: int = 0
    deadline_misses: int = 0
    degraded: int = 0
    shed: int = 0
    hedges: int = 0
    fast_fails: int = 0
    slow_fetches: int = 0   # flagged by the StragglerMonitor

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class RemoteSession:
    """Per-policy resilience state: the remote backend, its circuit
    breaker, the slow-fetch monitor, cumulative counters, and the full
    per-request report list (bench latency percentiles read it)."""

    def __init__(self, remote: Optional[RemoteBackend] = None,
                 cfg: Optional[ResilienceConfig] = None):
        self.remote = remote if remote is not None else OracleRemote()
        self.cfg = cfg if cfg is not None else ResilienceConfig()
        self.breaker = CircuitBreaker(self.cfg.breaker)
        # reused straggler detector (repro.train.fault): flags fetches
        # slower than slow_fetch_factor x the running median
        self.monitor = StragglerMonitor(
            threshold=self.cfg.slow_fetch_factor, window=64, quiet=True)
        self.counters = ResilienceCounters()
        self.reports: List[RequestReport] = []
        self.t = 0  # request counter = fault-schedule index

    def simulate_batch(self, b: int) -> List[RequestReport]:
        reps = [simulate_request(self.remote, t, self.cfg, self.breaker)
                for t in range(self.t, self.t + b)]
        c = self.counters
        for off, r in enumerate(reps):
            c.requests += 1
            c.retries += r.retries
            c.remote_failures += int(not r.ok)
            c.deadline_misses += int(r.deadline_miss)
            c.hedges += int(r.hedged)
            c.fast_fails += int(r.fast_failed)
            # slow-*fetch* detection: only completed fetches feed the
            # straggler monitor (failures are counted above, not "slow")
            if r.ok and self.monitor.record(
                    self.t + off, r.latency_ms / 1e3):
                c.slow_fetches += 1
        self.t += b
        self.reports.extend(reps)
        return reps

    def latency_percentiles(self) -> dict:
        lats = [r.latency_ms for r in self.reports if not r.fast_failed]
        if not lats:
            return {"p50_ms": 0.0, "p99_ms": 0.0}
        return {"p50_ms": float(np.percentile(lats, 50)),
                "p99_ms": float(np.percentile(lats, 99))}


# ---------------------------------------------------------------------------
# AÇAI degraded serving (jitted)
# ---------------------------------------------------------------------------

def degraded_serve(d: jax.Array, x_cand: jax.Array, k: int, c_f,
                   ceiling: float):
    """Local-only serve for one failed request: up to k cached candidates
    inside the cost ceiling, true dissimilarity costs booked.

    The ceiling is *relative* — a candidate is eligible when its
    dissimilarity is within `ceiling x` the request's best healthy-serve
    cost (nearest-candidate dissimilarity + c_f).  An absolute
    `ceiling * c_f` bound would be scale-dependent: on embeddings whose
    dissimilarities dwarf c_f it sheds everything, on ones below c_f it
    never sheds.  Relative to the healthy alternative, "within 2x of
    what a working remote would have cost" means the same thing on every
    catalog.

    Returns (gain, cost, served_local, shed).  Gain pairs the j-th
    cheapest served object against the j-th empty-cache answer slot
    (d_j + c_f, the cost the request would have paid with a healthy
    remote and an empty cache), clamped at 0 — the same reference the
    healthy serve's gain uses, so degraded gains stay comparable."""
    elig = (x_cand > 0.5) & (d <= ceiling * (jnp.min(d) + c_f))
    d_elig = jnp.where(elig, d, jnp.inf)
    neg, _ = jax.lax.top_k(-d_elig, k)
    d_served = -neg                       # +inf on unserved slots
    got = jnp.isfinite(d_served)
    neg_e, _ = jax.lax.top_k(-d, k)       # empty-cache answer slots
    empty_slots = -neg_e + c_f
    gain = jnp.sum(jnp.where(got, jnp.maximum(empty_slots - d_served, 0.0),
                             0.0))
    cost = jnp.sum(jnp.where(got, d_served, 0.0))
    n_served = jnp.sum(got.astype(jnp.int32))
    return gain, cost, n_served, n_served == 0


degraded_serve_batch = jax.vmap(degraded_serve,
                                in_axes=(0, 0, None, None, None))


def make_degraded_step(cfg: acai.AcaiConfig, batch: int, ceiling: float,
                       eta_scale: float | None = None) -> Callable:
    """Jitted mini-batch step for partially/fully failed batches:
    (state, ids, d, valid, ok (B,), alive) -> (state', StepMetrics (B,)).

    Mirrors `apply_candidates_batched` exactly on the OMA side — the
    subgradient needs only local distances, so y ascends on every
    request, failed or not — and overrides the *serving* outcome on
    failed rows with the degradation ladder.  The physical cache `x`
    freezes when the whole batch failed (a fetch needs the remote tier);
    with any success in the batch, rounding proceeds as usual."""
    cfg_up = acai.scaled_config(cfg, batch, eta_scale)

    @jax.jit
    def step(state: acai.CacheState, ids, d, valid, ok, alive):
        key, k_round = jax.random.split(state.key)
        n = state.y.shape[0]
        ids_c = jnp.clip(ids, None, n - 1)
        x_cand = jnp.where(valid, state.x[ids_c], 0.0)
        y_cand = jnp.where(valid, state.y[ids_c], 0.0)

        served = gain_lib.serve_batch(d, x_cand, cfg.k, cfg.c_f)
        deg_gain, deg_cost, deg_served, deg_shed = degraded_serve_batch(
            d, x_cand, cfg.k, cfg.c_f, ceiling)
        gain_frac, g_cand = gain_lib.gain_and_subgradient_batch(
            d, y_cand, cfg.k, cfg.c_f)

        g_full = (
            jnp.zeros_like(state.y)
            .at[ids_c.reshape(-1)]
            .add(jnp.where(valid, g_cand, 0.0).reshape(-1) / batch)
        )
        y_new = oma_lib.oma_update(state.y, g_full, cfg.h, cfg_up.oma)
        y_new = jnp.where(alive, y_new, 0.0)
        x_rounded = acai._round_state(cfg_up, k_round, y_new, state.y,
                                      state.x, state.t, width=batch)
        x_new = jnp.where(jnp.any(ok), x_rounded, state.x)
        moved = rounding_lib.movement(x_new, state.x)

        ok_b = ok.astype(bool)
        metrics = StepMetrics(
            gain_int=jnp.where(ok_b, served.gain, deg_gain),
            gain_frac=gain_frac,
            cost=jnp.where(ok_b, served.cost, deg_cost),
            served_local=jnp.where(
                ok_b, jnp.sum(served.from_cache.astype(jnp.int32), axis=1),
                deg_served),
            fetched=jnp.concatenate(
                [jnp.zeros((batch - 1,), moved.dtype), moved[None]]),
            occupancy=jnp.full((batch,), jnp.sum(x_new)),
            local_overflow=jnp.zeros((batch,), jnp.int32),
            degraded=(~ok_b & ~deg_shed).astype(jnp.int32),
            shed=(~ok_b & deg_shed).astype(jnp.int32),
            remote_failures=(~ok_b).astype(jnp.int32),
            answer_hits=jnp.zeros((batch,), jnp.int32),
            answer_misses=jnp.zeros((batch,), jnp.int32),
            answer_invalidations=jnp.zeros((batch,), jnp.int32),
        )
        return acai.CacheState(y_new, x_new, state.t + batch, key), metrics

    return step


class AcaiResilience:
    """The AÇAI cache's resilient serving mode (built by
    `AcaiCache.attach_remote`).

    Batches whose every request succeeded take the cache's *static jitted
    step unchanged* — at fault-rate 0 the resilient path is therefore
    bitwise identical to `make_replay_batched`.  Batches with failures
    run the two-stage degraded path: the candidate slab is generated
    eagerly (same generators as the mutable mode) and handed to the
    jitted `make_degraded_step` tail."""

    def __init__(self, cache, remote: Optional[RemoteBackend] = None,
                 resilience: Optional[ResilienceConfig] = None):
        self.cache = cache
        self.session = RemoteSession(remote, resilience)
        self._deg_steps: dict[int, Callable] = {}

    def serve_update_batch(self, rs: jax.Array) -> StepMetrics:
        rs = jnp.atleast_2d(rs)
        b = rs.shape[0]
        reps = self.session.simulate_batch(b)
        ok = np.array([r.ok for r in reps])
        retries = np.array([r.retries for r in reps], np.int32)
        misses = np.array([r.deadline_miss for r in reps], np.int32)
        cache = self.cache
        if ok.all():
            m = cache._serve_batch_direct(rs)
            if retries.any() or misses.any():  # recovered retries/lates
                m = m._replace(retries=jnp.asarray(retries),
                               deadline_misses=jnp.asarray(misses))
            return m
        # two-stage degraded path: eager slab + jitted degraded tail
        if cache._mutated:
            ids, d, valid = cache._mut_fn(rs, cache.state.x)
        else:
            ids, d, valid = cache._fn_batched(rs, cache.state.x)
        step = self._deg_steps.get(b)
        if step is None:
            step = make_degraded_step(cache.cfg, b,
                                      self.session.cfg.degrade_ceiling)
            self._deg_steps[b] = step
        cache.state, m = step(cache.state, ids, d, valid, jnp.asarray(ok),
                              cache.valid)
        self.session.counters.degraded += int(jnp.sum(m.degraded))
        self.session.counters.shed += int(jnp.sum(m.shed))
        if cache.answer_cache is not None:
            # book the answer-tier counters from the eager slab above,
            # same as AcaiCache._serve_batch_direct (DESIGN.md §13)
            mask, inval = cache.answer_cache.cache.take_step_stats(b)
            hits = jnp.asarray(mask, jnp.int32)
            m = m._replace(
                answer_hits=hits, answer_misses=1 - hits,
                answer_invalidations=jnp.zeros(
                    (b,), jnp.int32).at[0].set(int(inval)))
        return m._replace(retries=jnp.asarray(retries),
                          deadline_misses=jnp.asarray(misses))


# ---------------------------------------------------------------------------
# Generic policy wrapper (AÇAI delegates; baselines get the ladder here)
# ---------------------------------------------------------------------------

class ResilientPolicy:
    """CachePolicy wrapper adding the resilient remote tier to any
    registered policy.

    AÇAI policies delegate to the cache's own resilient mode
    (`AcaiCache.attach_remote`); baseline policies split each mini-batch
    into consecutive healthy runs — served through the inner policy
    unchanged — and per-request degraded serves
    (`KeyValueCache.step_degraded`) for the failures.  Every CachePolicy
    surface (spec/k/c_f/h, mutation, NAG) passes through, so harnesses
    never notice the wrapper."""

    def __init__(self, inner, remote: Optional[RemoteBackend] = None,
                 resilience: Optional[ResilienceConfig] = None):
        self.inner = inner
        cache = getattr(inner, "cache", None)
        if cache is not None and hasattr(cache, "attach_remote"):
            self._acai = True
            self.session = cache.attach_remote(remote, resilience).session
        else:
            self._acai = False
            self.session = RemoteSession(remote, resilience)

    spec = property(lambda self: self.inner.spec)
    k = property(lambda self: self.inner.k)
    c_f = property(lambda self: self.inner.c_f)
    h = property(lambda self: self.inner.h)

    def normalized_gain(self, total_gain: float, t: int) -> float:
        return self.inner.normalized_gain(total_gain, t)

    def add_objects(self, vectors):
        return self.inner.add_objects(vectors)

    def remove_objects(self, ids) -> None:
        self.inner.remove_objects(ids)

    def refresh(self) -> None:
        self.inner.refresh()

    def serve_update(self, r, t=None) -> StepMetrics:
        import jax.tree_util as jtu

        m = self.serve_update_batch(np.atleast_2d(np.asarray(r)),
                                    None if t is None else np.asarray([t]))
        return jtu.tree_map(lambda a: a[0], m)

    def serve_update_batch(self, rs, ts=None) -> StepMetrics:
        if self._acai:
            return self.inner.serve_update_batch(rs, ts)
        return self._baseline_batch(rs, ts)

    def _baseline_batch(self, rs, ts) -> StepMetrics:
        rs = np.atleast_2d(np.asarray(rs, np.float32))
        b = rs.shape[0]
        reps = self.session.simulate_batch(b)
        ok = np.array([r.ok for r in reps])
        cols = {f: np.zeros(b, np.float64) for f in
                ("gain_int", "gain_frac", "cost")}
        icols = {f: np.zeros(b, np.int32) for f in
                 ("served_local", "fetched", "degraded", "shed")}
        occ = np.zeros(b, np.float64)
        pol = self.inner.policy
        ceiling = self.session.cfg.degrade_ceiling
        i = 0
        while i < b:
            if ok[i]:
                j = i
                while j < b and ok[j]:
                    j += 1
                sub = self.inner.serve_update_batch(
                    rs[i:j], None if ts is None else np.asarray(ts)[i:j])
                for f in cols:
                    cols[f][i:j] = np.asarray(getattr(sub, f), np.float64)
                for f in ("served_local", "fetched"):
                    icols[f][i:j] = np.asarray(getattr(sub, f), np.int32)
                occ[i:j] = np.asarray(sub.occupancy, np.float64)
                i = j
            else:
                res, shed = pol.step_degraded(rs[i], ceiling=ceiling)
                cols["gain_int"][i] = cols["gain_frac"][i] = res.gain
                cols["cost"][i] = res.cost
                icols["served_local"][i] = res.served_local
                icols["degraded"][i] = int(not shed)
                icols["shed"][i] = int(shed)
                occ[i] = float(len(pol.cached_object_ids()))
                self.session.counters.degraded += int(not shed)
                self.session.counters.shed += int(shed)
                i += 1
        return StepMetrics(
            gain_int=cols["gain_int"], gain_frac=cols["gain_frac"],
            cost=cols["cost"], served_local=icols["served_local"],
            fetched=icols["fetched"], occupancy=occ,
            local_overflow=np.zeros(b, np.int32),
            degraded=icols["degraded"], shed=icols["shed"],
            remote_failures=(~ok).astype(np.int32),
            retries=np.array([r.retries for r in reps], np.int32),
            deadline_misses=np.array([r.deadline_miss for r in reps],
                                     np.int32),
            answer_hits=np.zeros(b, np.int32),
            answer_misses=np.zeros(b, np.int32),
            answer_invalidations=np.zeros(b, np.int32),
        )


def replay_resilient(pol, reqs, *, batch: int = 8) -> dict:
    """Drive a trace through a resilient policy and aggregate the
    resilience story: per-request metric arrays plus goodput (fraction of
    requests answered, healthy or degraded), degraded/shed shares,
    virtual latency percentiles, retry/deadline/hedge totals, and the
    breaker's transition count.  The generic driver behind
    `benchmarks/resilience_bench.py` and the smoke-test outage scenario."""
    import time as _time

    reqs = np.asarray(reqs)
    t = reqs.shape[0]
    tt = (t // batch) * batch
    if tt == 0:
        raise ValueError(f"trace of {t} requests is shorter than one "
                         f"mini-batch (batch={batch})")
    fields = ("gain_int", "cost", "served_local", "fetched", "occupancy",
              "degraded", "shed", "remote_failures", "retries",
              "deadline_misses")
    out = {f: [] for f in fields}
    times = []
    for s in range(0, tt, batch):
        t0 = _time.time()
        m = pol.serve_update_batch(reqs[s:s + batch], None)
        times.append(_time.time() - t0)
        for f in fields:
            out[f].append(np.asarray(getattr(m, f), np.float64))
    res = {f: np.concatenate(v) for f, v in out.items()}
    res["gain"] = res.pop("gain_int")
    res["hit"] = res["served_local"] > 0
    res["requests"] = tt
    res["p50_step_s"] = float(np.percentile(times, 50)) if times else 0.0
    ses = pol.session
    res["goodput"] = 1.0 - float(res["shed"].sum()) / tt
    res["degraded_share"] = float(res["degraded"].sum()) / tt
    res["shed_share"] = float(res["shed"].sum()) / tt
    res["counters"] = ses.counters.to_dict()
    res["breaker_transitions"] = ses.breaker.transitions
    res["breaker_log"] = list(ses.breaker.log)
    res.update(ses.latency_percentiles())
    return res
