"""Resilient serving tier (DESIGN.md §11): deterministic fault schedules,
retry/backoff/hedge/deadline semantics, the circuit-breaker state machine,
graceful degradation (AÇAI + baselines), input hygiene, the stale-answer
repair path, and the fault-rate-0 bitwise-parity pin vs
`make_replay_batched`."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import baselines as B
from repro.core import policy, trace
from repro.core import policy_api as PA
from repro.core.costs import CostModel
from repro.core.policy_api import TINY_POLICY_KWARGS as TINY
from repro.serve.remote import (FaultSpec, FaultyRemote, OracleRemote,
                                parse_outage_windows, payload_ok)
from repro.serve.resilience import (BreakerConfig, CircuitBreaker,
                                    ResilienceConfig, ResilientPolicy,
                                    RetryConfig, _backoff_ms,
                                    replay_resilient, simulate_request)
from repro.train.fault import StragglerMonitor


@pytest.fixture(scope="module")
def setup():
    catalog, reqs, _ = trace.sift_like(n=400, d=16, t=96, seed=0)
    return catalog, reqs, CostModel(c_f=1.0)


# ---------------------------------------------------------------------------
# fault schedule: deterministic, order-independent, per-attempt independent
# ---------------------------------------------------------------------------

def test_fault_schedule_deterministic_and_order_independent():
    spec = FaultSpec(error_rate=0.3, corrupt_rate=0.1, latency_sigma=0.4,
                     seed=7)
    a, b = FaultyRemote(spec), FaultyRemote(spec)
    # same (seed, t, attempt) -> same outcome, regardless of query order
    fwd = [a.outcome(t) for t in range(32)]
    rev = [b.outcome(t) for t in reversed(range(32))][::-1]
    assert fwd == rev
    # replays bit-for-bit on a fresh instance
    assert fwd == [FaultyRemote(spec).outcome(t) for t in range(32)]
    # a retry draws an independent (but reproducible) fate
    outs = {a.outcome(5, attempt=i) for i in range(16)}
    assert len(outs) > 1
    assert a.outcome(5, attempt=3) == b.outcome(5, attempt=3)
    # different seeds reshuffle the schedule
    other = FaultyRemote(FaultSpec(error_rate=0.3, corrupt_rate=0.1,
                                   latency_sigma=0.4, seed=8))
    assert [other.outcome(t) for t in range(32)] != fwd


def test_null_spec_is_always_ok():
    spec = FaultSpec()
    assert spec.is_null
    r = FaultyRemote(spec)
    assert all(r.outcome(t, a).ok for t in range(64) for a in range(3))
    assert not FaultSpec(error_rate=0.01).is_null
    assert not FaultSpec(outages=((3, 9),)).is_null


def test_outage_windows_and_parsing():
    spec = FaultSpec(outages=((10, 20),), seed=0)
    r = FaultyRemote(spec)
    assert spec.in_outage(10) and spec.in_outage(19)
    assert not spec.in_outage(9) and not spec.in_outage(20)
    assert r.outcome(15).kind == "outage"
    assert r.outcome(15, attempt=5).kind == "outage"  # retries can't help
    with pytest.raises(ConnectionError):
        r.fetch(np.zeros((1, 4), np.float32), 2, t=15)
    assert parse_outage_windows(["10:20", "40:50"]) == ((10, 20), (40, 50))
    with pytest.raises(ValueError):
        parse_outage_windows(["20:10"])
    with pytest.raises(ValueError):
        parse_outage_windows(["nope"])
    with pytest.raises(ValueError):
        FaultSpec(error_rate=1.5)


def test_corrupt_payload_detected_never_consumed(setup):
    catalog, reqs, _ = setup
    oracle = B.ServerOracle(catalog, kmax=8)
    r = FaultyRemote(FaultSpec(corrupt_rate=1.0), inner=OracleRemote(oracle))
    assert r.outcome(0).kind == "corrupt"
    ids, d2 = r.fetch(np.asarray(reqs[:2]), 4, t=0)
    assert np.isnan(d2).any()
    assert not payload_ok(ids, d2)          # the detection half
    clean = FaultyRemote(FaultSpec(), inner=OracleRemote(oracle))
    ids2, d22 = clean.fetch(np.asarray(reqs[:2]), 4, t=0)
    assert payload_ok(ids2, d22)
    assert not payload_ok(None)


# ---------------------------------------------------------------------------
# retry / backoff / hedge / deadline
# ---------------------------------------------------------------------------

def test_retry_accounting_and_recovery():
    cfg = ResilienceConfig(deadline_ms=None)
    # permanent failure: every retry burned, failure kind preserved
    rep = simulate_request(FaultyRemote(FaultSpec(error_rate=1.0)), 0, cfg)
    assert not rep.ok and rep.retries == cfg.retry.max_retries
    assert rep.failure_kind == "error"
    # flaky: some request recovers on a retry (ok with retries > 0)
    flaky = FaultyRemote(FaultSpec(error_rate=0.5, seed=2))
    reps = [simulate_request(flaky, t, cfg) for t in range(64)]
    assert any(r.ok and r.retries > 0 for r in reps)
    # healthy: no retries, no misses
    rep = simulate_request(FaultyRemote(FaultSpec()), 0, cfg)
    assert rep.ok and rep.retries == 0 and not rep.deadline_miss


def test_backoff_capped_exponential_with_jitter():
    rc = RetryConfig(backoff_ms=10.0, backoff_cap_ms=35.0, jitter=0.2)
    b0, b1, b2 = (_backoff_ms(rc, 0, 7, a) for a in range(3))
    assert 10.0 <= b0 <= 12.0          # base * (1 + U[0, j])
    assert 20.0 <= b1 <= 24.0          # doubled
    assert 35.0 <= b2 <= 42.0          # capped before jitter
    # deterministic per (seed, t, attempt); seed moves it
    assert b0 == _backoff_ms(rc, 0, 7, 0)
    assert b0 != _backoff_ms(rc, 1, 7, 0)
    rc0 = RetryConfig(backoff_ms=10.0, jitter=0.0)
    assert _backoff_ms(rc0, 0, 7, 0) == 10.0


class _ScriptedRemote:
    """attempt -> Outcome table (default ok@5ms), for exact-path tests."""

    def __init__(self, table):
        self.table = table

    def outcome(self, t, attempt=0):
        from repro.serve.remote import Outcome

        kind, lat = self.table.get(attempt, ("ok", 5.0))
        return Outcome(kind, lat)


def test_hedge_fires_on_slow_attempt_and_rescues():
    from repro.serve.resilience import HEDGE_ATTEMPT_OFFSET

    cfg = ResilienceConfig(deadline_ms=None, hedge_ms=50.0)
    # slow primary, fast hedge twin: completion = hedge_ms + hedge latency
    r = _ScriptedRemote({0: ("ok", 200.0),
                         HEDGE_ATTEMPT_OFFSET: ("ok", 10.0)})
    rep = simulate_request(r, 0, cfg)
    assert rep.ok and rep.hedged and rep.latency_ms == 60.0
    # fast primary: no hedge fires
    rep = simulate_request(_ScriptedRemote({0: ("ok", 20.0)}), 0, cfg)
    assert rep.ok and not rep.hedged and rep.latency_ms == 20.0
    # hedging off: slow primary just completes
    rep = simulate_request(_ScriptedRemote({0: ("ok", 200.0)}), 0,
                           ResilienceConfig(deadline_ms=None,
                                            retry=RetryConfig(
                                                attempt_timeout_ms=None)))
    assert rep.ok and not rep.hedged and rep.latency_ms == 200.0


def test_deadline_semantics():
    # a success landing past the deadline is a failure + a booked miss
    cfg = ResilienceConfig(deadline_ms=100.0,
                           retry=RetryConfig(attempt_timeout_ms=None))
    rep = simulate_request(_ScriptedRemote({0: ("ok", 150.0)}), 0, cfg)
    assert not rep.ok and rep.deadline_miss and rep.failure_kind == "deadline"
    # an attempt slower than its timeout is cancelled -> 'timeout'
    cfg = ResilienceConfig(deadline_ms=None,
                           retry=RetryConfig(max_retries=0,
                                             attempt_timeout_ms=100.0))
    rep = simulate_request(_ScriptedRemote({0: ("ok", 150.0)}), 0, cfg)
    assert not rep.ok and rep.failure_kind == "timeout"
    assert rep.latency_ms == 100.0
    # the retry loop stops once the budget is exhausted
    cfg = ResilienceConfig(deadline_ms=30.0,
                           retry=RetryConfig(max_retries=5,
                                             attempt_timeout_ms=20.0))
    rep = simulate_request(FaultyRemote(FaultSpec(error_rate=1.0,
                                                  error_latency_ms=25.0)),
                           0, cfg)
    assert not rep.ok and rep.retries < 5 and rep.deadline_miss


# ---------------------------------------------------------------------------
# circuit breaker state machine
# ---------------------------------------------------------------------------

def test_breaker_state_machine_and_decision_log():
    br = CircuitBreaker(BreakerConfig(failure_threshold=3,
                                      cooldown_requests=10,
                                      half_open_probes=1))
    for t in range(3):
        assert br.allow(t)
        br.record(t, False)
    assert br.state == "open"
    assert br.log[-1] == {"t": 2, "from": "closed", "to": "open",
                          "reason": "3 consecutive failures"}
    # open: fail fast through the cooldown
    assert not br.allow(5) and not br.allow(11)
    # cooldown elapsed: half-open admits exactly one probe
    assert br.allow(12) and br.state == "half_open"
    br.record(12, False)              # probe fails -> reopen
    assert br.state == "open" and not br.allow(13)
    assert br.log[-1]["reason"] == "probe failed"
    # second cooldown, successful probe -> closed
    assert br.allow(22)
    br.record(22, True)
    assert br.state == "closed"
    assert [e["to"] for e in br.log] == ["open", "half_open", "open",
                                         "half_open", "closed"]
    # a success resets the consecutive-failure count
    br.record(23, False)
    br.record(24, True)
    br.record(25, False)
    br.record(26, False)
    assert br.state == "closed"


def test_breaker_fast_fails_requests():
    cfg = ResilienceConfig(
        deadline_ms=None,
        breaker=BreakerConfig(failure_threshold=2, cooldown_requests=100))
    br = CircuitBreaker(cfg.breaker)
    remote = FaultyRemote(FaultSpec(error_rate=1.0))
    reps = [simulate_request(remote, t, cfg, br) for t in range(10)]
    assert not any(r.ok for r in reps)
    assert all(r.fast_failed for r in reps[2:])   # opened after 2 failures
    assert reps[5].failure_kind == "breaker_open"
    assert reps[5].retries == 0                   # not even attempted


# ---------------------------------------------------------------------------
# fault-rate 0: bitwise parity with the fault-oblivious pipeline
# ---------------------------------------------------------------------------

def test_fault_rate_zero_bitwise_parity(setup):
    catalog, reqs, cm = setup
    spec = PA.PolicySpec("acai", TINY["acai"])
    res_pol = ResilientPolicy(PA.build_policy(spec, catalog, cm, seed=0),
                              remote=FaultyRemote(FaultSpec()),
                              resilience=ResilienceConfig())
    ref_pol = PA.build_policy(spec, catalog, cm, seed=0)
    got = replay_resilient(res_pol, reqs, batch=8)
    ref = ref_pol.replay(reqs)       # make_replay_batched underneath
    # gains AND full policy state: the resilient path took the static
    # jitted step for every (all-ok) batch, so everything is bit-equal
    assert np.array_equal(got["gain"], np.asarray(ref["gain"]))
    ca, cb = res_pol.inner.cache, ref_pol.cache
    assert np.array_equal(np.asarray(ca.state.y), np.asarray(cb.state.y))
    assert np.array_equal(np.asarray(ca.state.x), np.asarray(cb.state.x))
    assert got["counters"]["remote_failures"] == 0
    assert got["goodput"] == 1.0 and got["degraded_share"] == 0.0


# ---------------------------------------------------------------------------
# degradation ladder: AÇAI
# ---------------------------------------------------------------------------

def test_outage_degrades_freezes_x_keeps_state_finite(setup):
    catalog, reqs, cm = setup
    spec = PA.PolicySpec("acai", TINY["acai"])
    pol = ResilientPolicy(
        PA.build_policy(spec, catalog, cm, seed=0),
        remote=FaultyRemote(FaultSpec(outages=((0, 10 ** 9),))),
        resilience=ResilienceConfig())
    cache = pol.inner.cache
    x0 = np.asarray(cache.state.x).copy()
    y0 = np.asarray(cache.state.y).copy()
    m = pol.serve_update_batch(jnp.asarray(reqs[:8]))
    # every request failed: the ladder served (degraded) or shed, never
    # a healthy remote fetch; failure bookkeeping is per request
    assert np.asarray(m.remote_failures).sum() == 8
    assert (np.asarray(m.degraded) + np.asarray(m.shed)).sum() == 8
    # physical cache frozen (a fetch needs the remote tier)...
    assert np.array_equal(np.asarray(cache.state.x), x0)
    # ...but the OMA ascent continued on local distances, and stayed finite
    y1 = np.asarray(cache.state.y)
    assert not np.array_equal(y1, y0)
    assert np.isfinite(y1).all()
    # degraded rows book true dissimilarity cost, shed rows book nothing
    deg = np.asarray(m.degraded).astype(bool)
    assert (np.asarray(m.cost)[deg] >= 0).all()
    assert (np.asarray(m.served_local)[deg] > 0).all()
    assert (np.asarray(m.served_local)[np.asarray(m.shed).astype(bool)]
            == 0).all()


def test_partial_failure_batch_still_updates_x(setup):
    catalog, reqs, cm = setup
    spec = PA.PolicySpec("acai", TINY["acai"])
    pol = ResilientPolicy(
        PA.build_policy(spec, catalog, cm, seed=0),
        remote=FaultyRemote(FaultSpec(error_rate=0.4, seed=1)),
        resilience=ResilienceConfig())
    res = replay_resilient(pol, reqs, batch=8)
    c = res["counters"]
    assert 0 < c["remote_failures"] < c["requests"]
    # mixed batches exist, so rounding proceeded: occupancy stays at h
    assert np.allclose(res["occupancy"], pol.h)
    assert np.isfinite(np.asarray(pol.inner.cache.state.y)).all()


# ---------------------------------------------------------------------------
# degradation ladder: baselines
# ---------------------------------------------------------------------------

def test_baseline_resilient_path(setup):
    catalog, reqs, cm = setup
    spec = PA.PolicySpec("sim_lru", TINY["sim_lru"])
    oracle = B.ServerOracle(catalog, kmax=16)
    # warm the cache with a healthy prefix, then a hard outage
    pol = ResilientPolicy(
        PA.build_policy(spec, catalog, cm, oracle=oracle, seed=0),
        remote=FaultyRemote(FaultSpec(outages=((48, 96),))),
        resilience=ResilienceConfig())
    res = replay_resilient(pol, reqs, batch=8)
    c = res["counters"]
    assert c["remote_failures"] >= 48 - 8  # outage + breaker ringing
    assert c["degraded"] + c["shed"] == c["remote_failures"]
    # the healthy prefix really served through the inner policy
    assert res["gain"][:48].sum() > 0
    assert np.asarray(res["degraded"])[:40].sum() == 0
    # metrics keep the StepMetrics contract (per-request vectors)
    m = pol.serve_update_batch(reqs[:8])
    for f in policy.StepMetrics._fields:
        assert np.asarray(getattr(m, f)).shape == (8,), f
    # B = 1 view
    m1 = pol.serve_update(reqs[0])
    assert np.asarray(m1.gain_int).shape == ()


def test_step_degraded_relative_ceiling(setup):
    catalog, _, cm = setup
    oracle = B.ServerOracle(catalog, kmax=16)
    pol = B.SimLRU(catalog, oracle, h=16, k=4, k_prime=8, c_theta=1.5,
                   c_f=cm.c_f)
    rng = np.random.default_rng(0)
    # empty cache: nothing local -> shed, zero gain
    res, shed = pol.step_degraded(catalog[0] + 0.01 * rng.normal(size=16)
                                  .astype(np.float32))
    assert shed and res.gain == 0.0 and res.served_local == 0
    # warm the cache, then re-ask the most recent request: its k' server
    # answers are cached, the nearest at distance ~0 -> gain ~= c_f
    ts = oracle.extend(catalog[:8])
    for t, r in zip(ts, catalog[:8]):
        pol.step(int(t), r)
    res, shed = pol.step_degraded(catalog[7])
    assert not shed and res.served_local > 0 and res.gain > 0
    assert res.fetched == 0   # degraded mode never inserts
    # and the LRU state was untouched: no new entry, no reorder
    before = list(pol.entries)
    pol.step_degraded(catalog[7])
    assert list(pol.entries) == before


# ---------------------------------------------------------------------------
# input hygiene: NaN/Inf queries rejected at every entry point
# ---------------------------------------------------------------------------

def test_poisoned_queries_rejected(setup):
    catalog, reqs, cm = setup
    bad = np.asarray(reqs[:8]).copy()
    bad[3, 0] = np.nan
    bad_inf = np.asarray(reqs[:8]).copy()
    bad_inf[1, 2] = np.inf

    pol = PA.build_policy(PA.PolicySpec("acai", TINY["acai"]), catalog, cm)
    y0 = np.asarray(pol.cache.state.y).copy()
    with pytest.raises(ValueError, match="NaN/Inf"):
        pol.serve_update_batch(bad)
    with pytest.raises(ValueError, match="NaN/Inf"):
        pol.cache.serve_update(jnp.asarray(bad[3]))
    # rejection happened before any state was touched
    assert np.array_equal(np.asarray(pol.cache.state.y), y0)

    oracle = B.ServerOracle(catalog, kmax=16)
    bpol = PA.build_policy(PA.PolicySpec("sim_lru", TINY["sim_lru"]),
                           catalog, cm, oracle=oracle)
    with pytest.raises(ValueError, match="NaN/Inf"):
        bpol.serve_update_batch(bad_inf)

    from repro.index.base import IndexSpec, build_index
    idx = build_index(IndexSpec("flat"), np.asarray(catalog))
    with pytest.raises(ValueError, match="NaN/Inf"):
        idx.query(bad, 4)


# ---------------------------------------------------------------------------
# satellite fixes: straggler median, stale-answer repair
# ---------------------------------------------------------------------------

def test_straggler_monitor_even_window_median():
    warm = [1.0, 1.0, 1.0, 5.0, 5.0]
    mon = StragglerMonitor(threshold=2.0, window=8)
    for i, s in enumerate(warm):
        mon.record(i, s)
    # window [1, 1, 1, 5, 5, 6.5]: true median 3.0 -> 6.5 > 2 * 3 flags;
    # the old upper-middle "median" (5.0) needed > 10 and missed it
    assert mon.record(5, 6.5)
    assert mon.flagged[-1] == (5, 6.5)
    # quiet mode still records + flags (counters report, log stays silent)
    q = StragglerMonitor(threshold=2.0, window=8, quiet=True)
    for i, s in enumerate(warm):
        q.record(i, s)
    assert q.record(5, 6.5) and q.flagged


def test_server_oracle_stale_repair(setup):
    catalog, reqs, _ = setup
    oracle = B.ServerOracle(catalog, requests=reqs[:8], kmax=8)
    ids0, _ = oracle.knn(0, 4)
    oracle.add_objects(np.asarray(reqs[8:9], np.float32))  # invalidates
    with pytest.raises(KeyError):
        oracle.knn(0, 4)            # bare stale read still raises (PR-5 pin)
    assert oracle.remote_recomputes == 0
    n = oracle.ensure(np.arange(8), np.asarray(reqs[:8]))
    assert n == 8 and oracle.remote_recomputes == 8
    ids1, d21 = oracle.knn(0, 4)
    assert ids1.shape == (4,) and np.isfinite(d21).all()
    assert oracle.empty_cost(0, 4, 1.0) > 0
    assert oracle.knn_block(np.arange(8), 4).shape == (8, 4)
    # a healthy (retained) table needs no repair at all
    fresh = B.ServerOracle(catalog, requests=reqs[:8], kmax=8)
    assert fresh.ensure(np.arange(8), np.asarray(reqs[:8])) == 0
    assert fresh.remote_recomputes == 0


def test_kv_cache_step_batch_repairs_through_oracle(setup):
    """A churned catalog no longer crashes the batched baselines: stale
    answer-table reads route through ensure() as booked remote calls."""
    catalog, reqs, cm = setup
    oracle = B.ServerOracle(catalog, requests=reqs[:16], kmax=16)
    pol = B.SimLRU(catalog, oracle, h=16, k=4, k_prime=8, c_theta=1.5,
                   c_f=cm.c_f)
    pol.step_batch(np.arange(8), np.asarray(reqs[:8]))
    oracle.add_objects(np.asarray(reqs[90:92], np.float32))
    pol.catalog = oracle.catalog    # baselines score against the live rows
    results = pol.step_batch(np.arange(8, 16), np.asarray(reqs[8:16]))
    assert len(results) == 8
    assert oracle.remote_recomputes == 8
