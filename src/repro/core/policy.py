"""AÇAI policy: request serving + OMA cache updates (paper Sec. IV).

Three entry points:

* `make_replay(...)` — a fully-jitted `lax.scan` over a request trace,
  carrying (y_t, x_t, key).  Per request it (1) builds the candidate set
  from the two indexes, (2) serves per Eq. (2) from x_t, (3) computes the
  subgradient Eq. (55) at y_t, (4) applies OMA + projection, (5) rounds to
  x_{t+1}.

* `make_replay_batched(...)` — the benchmark/serving hot path: scans the
  trace in request *mini-batches* of size B, vmapping serve/gain/
  subgradient per request and folding the batch into a single OMA +
  projection + rounding update (mini-batch mirror ascent, DESIGN.md §6).
  Bit-exact with make_replay at B = 1.

* `AcaiCache` — an object wrapper over the same jitted steps for the
  serving tier (repro.serve.semantic_cache) where requests arrive one by
  one (`serve_update`) or in batches (`serve_update_batch`).

Candidate sets: the union of kNN(r, local catalog) and kNN(r, remote
catalog) as returned by the two (approximate) indexes, deduplicated by
masking (duplicates get cost BIG and weight 0 so they are exactly neutral
in the augmented-catalog accounting — see repro.core.gain).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gain as gain_lib
from repro.core import oma as oma_lib
from repro.core import rounding as rounding_lib
from repro.core.costs import BIG_COST, pairwise_dissimilarity
from repro.index.base import IndexSpec, build_index


class StepMetrics(NamedTuple):
    gain_int: jax.Array    # G(r_t, x_t) — what the system actually earns
    gain_frac: jax.Array   # G(r_t, y_t) — fractional gain (analysis)
    cost: jax.Array        # C(r_t, x_t)
    served_local: jax.Array  # how many of the k answers came from the cache
    fetched: jax.Array     # cache-update traffic (# objects fetched)
    occupancy: jax.Array   # sum x_t
    # debug-mode counter (AcaiConfig.debug): cached rows the candidate
    # generator's static `local_cap` gather silently truncated this step —
    # max(0, |x_t| - cap), 0 when debug is off or the generator is uncapped
    # (see repro.index.candidates._local_cap).
    local_overflow: jax.Array | int = 0
    # resilient-serving counters (DESIGN.md §11): all zero on the
    # fault-free path, populated by repro.serve.resilience when a
    # RemoteBackend is attached.
    degraded: jax.Array | int = 0         # served locally under failure
    shed: jax.Array | int = 0             # failed, nothing local in ceiling
    remote_failures: jax.Array | int = 0  # request's remote tier failed
    retries: jax.Array | int = 0          # extra attempts beyond the first
    deadline_misses: jax.Array | int = 0  # deadline budget exceeded
    # answer-cache tier counters (DESIGN.md §13): all zero without an
    # AnswerCacheSpec, booked host-side by AcaiCache._serve_batch_direct.
    answer_hits: jax.Array | int = 0      # request's answer was memoized
    answer_misses: jax.Array | int = 0    # request needed the fused scan
    answer_invalidations: jax.Array | int = 0  # entries dropped by churn
                                               # since the previous step
                                               # (booked on the batch's
                                               # first request)


def shed_only_metrics(batch: int) -> StepMetrics:
    """StepMetrics rows for requests that never reached a policy step:
    zero gain/cost/occupancy with ``shed = 1`` on every row.

    The online serving engine's admission control (queue-depth cap,
    deadline shedding — DESIGN.md §12) books its victims through this
    helper, so engine-level shedding lands in the *same* counters the
    resilient tier populates (DESIGN.md §11) and downstream aggregation
    (NAG, goodput, shed share) never branches on who shed the request.
    """
    zf = np.zeros(batch, np.float64)
    zi = np.zeros(batch, np.int32)
    return StepMetrics(
        gain_int=zf, gain_frac=zf.copy(), cost=zf.copy(),
        served_local=zi, fetched=zi.copy(), occupancy=zf.copy(),
        local_overflow=zi.copy(), degraded=zi.copy(),
        shed=np.ones(batch, np.int32), remote_failures=zi.copy(),
        retries=zi.copy(), deadline_misses=zi.copy(),
        answer_hits=zi.copy(), answer_misses=zi.copy(),
        answer_invalidations=zi.copy())


class CacheState(NamedTuple):
    y: jax.Array  # (N,) fractional state
    x: jax.Array  # (N,) physical cache indicator
    t: jax.Array  # step counter
    key: jax.Array


def dedup_mask(ids: jax.Array, n: int) -> jax.Array:
    """valid[i] = ids[i] is a real id (< n) and its first occurrence."""
    order = jnp.argsort(ids, stable=True)
    sorted_ids = ids[order]
    dup_sorted = jnp.concatenate(
        [jnp.zeros((1,), bool), sorted_ids[1:] == sorted_ids[:-1]]
    )
    dup = jnp.zeros_like(dup_sorted).at[order].set(dup_sorted)
    return (ids < n) & ~dup


dedup_mask_batched = jax.vmap(dedup_mask, in_axes=(0, None))


def exact_candidate_fn_batched(
    catalog: jax.Array, c_remote: int, c_local: int, metric: str = "sqeuclidean"
) -> Callable:
    """Batched candidate generator backed by exact (flat) search on both
    sides: (B, d) requests x (N,) cache state -> (B, C) candidate slabs.

    Models *perfect-recall* indexes; the approximate variants live in
    repro.index.candidates (same signatures) and plug in here.  One (B, N)
    distance GEMM feeds both the remote top-k and the cached-row top-k, so
    the MXU sees the whole mini-batch at once (DESIGN.md §6).
    """
    n = catalog.shape[0]

    def fn(rs: jax.Array, x: jax.Array):
        b = rs.shape[0]
        d_full = pairwise_dissimilarity(rs, catalog, metric)     # (B, N)
        _, ids_remote = jax.lax.top_k(-d_full, c_remote)
        d_cached = jnp.where(x[None, :] > 0.5, d_full, jnp.inf)
        _, ids_local = jax.lax.top_k(-d_cached, c_local)
        ids = jnp.concatenate([ids_remote, ids_local], axis=1)
        valid = dedup_mask_batched(ids, n)
        # a "local" candidate slot is only valid if that object is cached
        cached_ok = jnp.concatenate(
            [jnp.ones((b, c_remote), bool), x[ids_local] > 0.5], axis=1
        )
        valid = valid & cached_ok
        d = jnp.where(
            valid,
            jnp.take_along_axis(d_full, jnp.clip(ids, 0, n - 1), axis=1),
            BIG_COST,
        )
        return ids, d, valid

    return fn


def per_request_view(candidate_fn_batched: Callable) -> Callable:
    """Adapt a batched candidate generator to the per-request signature
    fn(r (d,), x (N,)) -> (ids (C,), d (C,), valid (C,)) as its B = 1 view,
    so sequential and batched replays share one code path bit-for-bit."""

    def fn(r: jax.Array, x: jax.Array):
        ids, d, valid = candidate_fn_batched(r[None, :], x)
        return ids[0], d[0], valid[0]

    if hasattr(candidate_fn_batched, "local_cap"):
        fn.local_cap = candidate_fn_batched.local_cap
    return fn


def exact_candidate_fn(
    catalog: jax.Array, c_remote: int, c_local: int, metric: str = "sqeuclidean"
) -> Callable:
    """Per-request view of exact_candidate_fn_batched (B = 1)."""
    return per_request_view(
        exact_candidate_fn_batched(catalog, c_remote, c_local, metric)
    )


@partial(jax.jit, static_argnames=("c_remote", "c_local", "metric"))
def exact_mutable_candidates(
    rs: jax.Array, x: jax.Array, catalog: jax.Array, alive: jax.Array,
    c_remote: int, c_local: int, metric: str = "sqeuclidean",
):
    """Mutable-catalog twin of `exact_candidate_fn_batched` (DESIGN.md §10).

    Same math, but the catalog slab and its liveness mask are *runtime*
    arguments: online add/remove/refresh changes only array values, so the
    serving step never retraces at fixed capacity (shapes move only on
    capacity-doubling growth).  Tombstoned/unassigned rows scan as +inf
    and resolve to invalid slots, so a removed object can never be served
    or fetched.  With `alive` all-True the outputs match the static
    generator exactly.

    Returns (ids (B, C), dists (B, C), valid (B, C)) — the shared
    candidate-slab layout (C = c_remote + c_local, id = N marks an invalid
    slot, BIG_COST on its distance).
    """
    n = catalog.shape[0]
    b = rs.shape[0]
    d_full = pairwise_dissimilarity(rs, catalog, metric)         # (B, N)
    d_full = jnp.where(alive[None, :], d_full, jnp.inf)
    neg_r, ids_remote = jax.lax.top_k(-d_full, c_remote)
    # a dead/unassigned row can only be selected when fewer than c_remote
    # rows are live; flag it with the invalid sentinel n
    ids_remote = jnp.where(jnp.isfinite(neg_r), ids_remote, n)
    d_cached = jnp.where(x[None, :] > 0.5, d_full, jnp.inf)
    _, ids_local = jax.lax.top_k(-d_cached, c_local)
    ids = jnp.concatenate([ids_remote, ids_local], axis=1)
    valid = dedup_mask_batched(ids, n)
    # a "local" candidate slot is only valid if that object is cached (the
    # x(dead) = 0 invalidation invariant also keeps removed rows out here)
    cached_ok = jnp.concatenate(
        [jnp.ones((b, c_remote), bool), x[ids_local] > 0.5], axis=1
    )
    valid = valid & cached_ok
    d = jnp.where(
        valid,
        jnp.take_along_axis(d_full, jnp.clip(ids, 0, n - 1), axis=1),
        BIG_COST,
    )
    return ids, d, valid


@dataclasses.dataclass(frozen=True)
class AcaiConfig:
    h: int                      # cache capacity (objects)
    k: int = 10                 # answers per request
    c_f: float = 1.0            # fetching cost
    c_remote: int = 64          # remote-index candidates (>= k!)
    c_local: int = 16           # local-index candidates
    oma: oma_lib.OMAConfig = dataclasses.field(default_factory=oma_lib.OMAConfig)
    # remote-catalog index selection (DESIGN.md §8): an IndexSpec such as
    # IndexSpec("ivf", {"nlist": 256}) makes AcaiCache build its candidate
    # generator through repro.index.base.build_index; None = exact
    # (perfect-recall) candidates.  On a mesh, "ivf_sharded" selects the
    # per-shard IVF probe; None = the exact sharded scan.
    index: "IndexSpec | None" = None
    # debug instrumentation: books StepMetrics.local_overflow (cached rows
    # truncated by the candidate generator's static local_cap gather).
    debug: bool = False


def _round_state(cfg: AcaiConfig, key, y_new, y_old, x_old, t, width=1):
    mode = cfg.oma.rounding
    if mode == "coupled":
        return rounding_lib.coupled_rounding(key, x_old, y_old, y_new)
    if mode == "independent":
        return rounding_lib.independent_rounding(key, y_new)
    if mode == "depround":
        # Re-round every M requests (Alg. 1 lines 7-9), freeze in between.
        # A batched step covers requests [t, t + width); fire iff a multiple
        # of M lands in that window, so the cadence stays ~M (not
        # lcm(M, width)).  width = 1 reduces to t % M == 0.
        return jax.lax.cond(
            ((-t) % cfg.oma.round_every) < width,
            lambda _: rounding_lib.depround(key, y_new),
            lambda _: x_old,
            None,
        )
    raise ValueError(mode)


def _overflow_counter(cfg: AcaiConfig, candidate_fn: Callable,
                      x: jax.Array) -> jax.Array:
    """Debug-mode truncation counter: how many cached rows exceed the
    candidate generator's static `local_cap` gather bound (those rows are
    silently hidden from local serving — quality loss, not an error)."""
    cap = getattr(candidate_fn, "local_cap", None)
    if not cfg.debug or cap is None:
        return jnp.zeros((), jnp.int32)
    occ = jnp.sum((x > 0.5).astype(jnp.int32))
    return jnp.maximum(occ - cap, 0)


def make_step(cfg: AcaiConfig, candidate_fn: Callable) -> Callable:
    """Build the jitted per-request step: (state, r) -> (state', metrics)."""

    def step(state: CacheState, r: jax.Array):
        key, k_round = jax.random.split(state.key)
        ids, d, valid = candidate_fn(r, state.x)
        x_cand = jnp.where(valid, state.x[jnp.clip(ids, None, state.x.shape[0] - 1)], 0.0)
        y_cand = jnp.where(valid, state.y[jnp.clip(ids, None, state.y.shape[0] - 1)], 0.0)

        served = gain_lib.serve(d, x_cand, cfg.k, cfg.c_f)
        gain_frac, g_cand = gain_lib.gain_and_subgradient(d, y_cand, cfg.k, cfg.c_f)

        g_full = (
            jnp.zeros_like(state.y)
            .at[jnp.clip(ids, None, state.y.shape[0] - 1)]
            .add(jnp.where(valid, g_cand, 0.0))
        )
        y_new = oma_lib.oma_update(state.y, g_full, cfg.h, cfg.oma)
        x_new = _round_state(cfg, k_round, y_new, state.y, state.x, state.t)

        zero = jnp.zeros((), jnp.int32)
        metrics = StepMetrics(
            gain_int=served.gain,
            gain_frac=gain_frac,
            cost=served.cost,
            served_local=jnp.sum(served.from_cache.astype(jnp.int32)),
            fetched=rounding_lib.movement(x_new, state.x),
            occupancy=jnp.sum(x_new),
            local_overflow=_overflow_counter(cfg, candidate_fn, state.x),
            degraded=zero, shed=zero, remote_failures=zero, retries=zero,
            deadline_misses=zero,
        )
        return CacheState(y_new, x_new, state.t + 1, key), metrics

    return step


def init_state(n: int, cfg: AcaiConfig, seed: int = 0, start: str = "uniform") -> CacheState:
    """start='uniform': y_1 = argmin Phi (Alg. 1 line 1); 'empty': cold cache."""
    y = oma_lib.uniform_state(n, cfg.h)
    key = jax.random.PRNGKey(seed)
    key, k0 = jax.random.split(key)
    if start == "uniform":
        x = rounding_lib.depround(k0, y)
    else:
        x = jnp.zeros((n,), jnp.float32)
    return CacheState(y=y, x=x, t=jnp.zeros((), jnp.int32), key=key)


def make_replay(cfg: AcaiConfig, candidate_fn: Callable) -> Callable:
    """Whole-trace replay: (state, requests (T,d)) -> (state', StepMetrics (T,))."""
    step = make_step(cfg, candidate_fn)

    @jax.jit
    def replay(state: CacheState, requests: jax.Array):
        return jax.lax.scan(step, state, requests)

    return replay


def finish_step_batched(cfg_up: AcaiConfig, state: CacheState, key, k_round,
                        batch: int, y_new, gain_int, gain_frac, cost,
                        served_local, local_overflow=None):
    """Shared tail of every mini-batch step: rounding + metric assembly +
    state advance.  Used by both `make_step_batched` and
    `repro.core.distributed.make_step_sharded` so the two stay
    bit-consistent by construction (§6 metric reduction: `fetched` books
    the batch's cache-update traffic on its last request, `occupancy`
    repeats the post-update value, `local_overflow` — a per-batch scalar
    like occupancy — repeats the pre-update debug counter)."""
    x_new = _round_state(cfg_up, k_round, y_new, state.y, state.x, state.t,
                         width=batch)
    moved = rounding_lib.movement(x_new, state.x)
    if local_overflow is None:
        local_overflow = jnp.zeros((), jnp.int32)
    zeros = jnp.zeros((batch,), jnp.int32)  # resilience counters: always
    # materialized as arrays so tree_map/reshape over metrics never meets
    # a Python-int leaf (repro.serve.resilience overrides them per batch)
    metrics = StepMetrics(
        gain_int=gain_int, gain_frac=gain_frac, cost=cost,
        served_local=served_local,
        fetched=jnp.concatenate(
            [jnp.zeros((batch - 1,), moved.dtype), moved[None]]),
        occupancy=jnp.full((batch,), jnp.sum(x_new)),
        local_overflow=jnp.full((batch,), local_overflow),
        degraded=zeros, shed=zeros, remote_failures=zeros, retries=zeros,
        deadline_misses=zeros, answer_hits=zeros, answer_misses=zeros,
        answer_invalidations=zeros,
    )
    return CacheState(y_new, x_new, state.t + batch, key), metrics


def make_step_batched(
    cfg: AcaiConfig, candidate_fn_batched: Callable, batch: int,
    eta_scale: float | None = None,
) -> Callable:
    """Mini-batch step: (state, requests (B, d)) -> (state', StepMetrics (B,)).

    Mini-batch online mirror ascent (DESIGN.md §6): all B requests are
    served and differentiated against the *same* state x_t / y_t (candidate
    generation, serve and gain/subgradient vmap per request), the
    subgradients are batch-averaged, and a single OMA + projection +
    rounding update advances the state — the delayed-subgradient form whose
    regret the paper's analysis tolerates.  `eta_scale` (default: B)
    multiplies the learning rate so one averaged step moves as far as B
    sequential steps to first order; at B = 1 everything reduces
    bit-exactly to make_step.

    Metric reduction keeps figures B-invariant: serve metrics are per
    request (vs x_t); `fetched` books the batch's cache-update traffic on
    its last request (zero on the rest); `occupancy` repeats the
    post-update value.
    """
    cfg_up = scaled_config(cfg, batch, eta_scale)

    def step(state: CacheState, rs: jax.Array):
        ids, d, valid = candidate_fn_batched(rs, state.x)     # (B, C)
        return apply_candidates_batched(
            cfg, cfg_up, state, batch, ids, d, valid,
            local_overflow=_overflow_counter(cfg, candidate_fn_batched,
                                             state.x))

    return step


def apply_candidates_batched(cfg: AcaiConfig, cfg_up: AcaiConfig,
                             state: CacheState, batch: int, ids, d, valid,
                             alive=None, local_overflow=None):
    """Shared serve+update tail of every mini-batch step: consumes a
    precomputed candidate slab (ids, d, valid) and runs serve (Eq. 2),
    gain/subgradient (Eq. 55), the averaged OMA + projection update, and
    `finish_step_batched`.  `make_step_batched` traces it right after its
    candidate generator; the mutable-catalog step (`make_mutable_step`)
    jits it standalone, with `alive` enforcing the invalidation invariant
    (y = x = 0 on tombstoned rows, DESIGN.md §10).  One tail, two serving
    modes — with `alive=None` the computation is exactly the static path's.
    """
    key, k_round = jax.random.split(state.key)
    n = state.y.shape[0]
    ids_c = jnp.clip(ids, None, n - 1)
    x_cand = jnp.where(valid, state.x[ids_c], 0.0)
    y_cand = jnp.where(valid, state.y[ids_c], 0.0)

    served = gain_lib.serve_batch(d, x_cand, cfg.k, cfg.c_f)
    gain_frac, g_cand = gain_lib.gain_and_subgradient_batch(
        d, y_cand, cfg.k, cfg.c_f
    )

    g_full = (
        jnp.zeros_like(state.y)
        .at[ids_c.reshape(-1)]
        .add(jnp.where(valid, g_cand, 0.0).reshape(-1) / batch)
    )
    y_new = oma_lib.oma_update(state.y, g_full, cfg.h, cfg_up.oma)
    if alive is not None:
        # invalidation invariant: no fractional mass on dead rows (the
        # Y_FLOOR clip would otherwise resurrect them with 1e-12 mass,
        # and rounding could then physically cache a removed object)
        y_new = jnp.where(alive, y_new, 0.0)
    return finish_step_batched(
        cfg_up, state, key, k_round, batch, y_new, served.gain,
        gain_frac, served.cost,
        jnp.sum(served.from_cache.astype(jnp.int32), axis=1),
        local_overflow=local_overflow)


def scaled_config(cfg: AcaiConfig, batch: int,
                  eta_scale: float | None = None) -> AcaiConfig:
    """Mini-batch learning-rate scaling (DESIGN.md §6): one averaged OMA
    step moves as far as `batch` sequential steps to first order."""
    scale = float(batch) if eta_scale is None else float(eta_scale)
    return dataclasses.replace(
        cfg, oma=dataclasses.replace(cfg.oma, eta=cfg.oma.eta * scale))


def make_mutable_step(cfg: AcaiConfig, batch: int,
                      eta_scale: float | None = None) -> Callable:
    """Jitted tail for the mutable-catalog serving mode (DESIGN.md §10):
    (state, ids, d, valid, alive) -> (state', StepMetrics (B,)).

    Candidate slabs are generated *eagerly* against the current index
    structures (which mutate between steps, so they cannot be closed over
    by a cached jit) and handed to this step; `alive` is the catalog's
    liveness mask, threaded as a runtime argument so add/remove/refresh
    never retraces at fixed capacity.  With `alive` all-True the state
    advance matches `make_step_batched`'s exactly.
    """
    cfg_up = scaled_config(cfg, batch, eta_scale)

    @jax.jit
    def step(state: CacheState, ids, d, valid, alive):
        return apply_candidates_batched(cfg, cfg_up, state, batch, ids, d,
                                        valid, alive=alive)

    return step


def make_replay_from_step(step: Callable, batch: int) -> Callable:
    """Wrap a mini-batch step ((state, (B, d)) -> (state', metrics (B,)))
    into a whole-trace replay: (state, requests (T, d)) -> (state',
    StepMetrics (T,)), T divisible by batch, metrics flattened per request
    so downstream figure code is batch-invariant.  Shared by
    `make_replay_batched` and `repro.core.distributed.make_replay_sharded`
    — one replay contract, two step implementations."""

    @jax.jit
    def replay(state: CacheState, requests: jax.Array):
        t, dim = requests.shape
        assert t % batch == 0, (
            f"trace length {t} must divide by batch size {batch}"
        )
        state, m = jax.lax.scan(
            step, state, requests.reshape(t // batch, batch, dim)
        )
        return state, jax.tree_util.tree_map(
            lambda a: a.reshape(t, *a.shape[2:]), m
        )

    return replay


def make_replay_batched(
    cfg: AcaiConfig, candidate_fn_batched: Callable, batch: int,
    eta_scale: float | None = None,
) -> Callable:
    """Mini-batched whole-trace replay.

    (state, requests (T, d)) -> (state', StepMetrics (T,)): the trace is
    scanned in (T / batch) mini-batches (T must divide), metrics come back
    flattened per request so downstream figure code is unchanged.  At
    batch = 1 this is bit-exact with make_replay.
    """
    return make_replay_from_step(
        make_step_batched(cfg, candidate_fn_batched, batch, eta_scale), batch)


class AcaiCache:
    """Object API over the jitted step, for the online serving tier.

    Backend selection is config-driven (DESIGN.md §8): when
    `cfg.index` holds an `IndexSpec`, the remote-catalog index is built
    through `repro.index.base.build_index` and wired into the candidate
    slabs via `repro.index.candidates.index_candidate_fn_batched`; with
    `cfg.index = None` candidates are exact (perfect recall).

    Escape hatch (the pre-IndexSpec wiring, kept for custom generators): a
    per-request `candidate_fn` or batched `candidate_fn_batched` overrides
    the spec-built generator.  Passing one *alongside* `cfg.index` is
    deprecated — the explicit fn silently wins, which defeats the config
    knob — and warns.

    `mesh` switches both entry points to the sharded multi-device step
    (`repro.core.distributed.make_step_sharded`): catalog and cache state
    shard over the mesh's `model` axis, the candidate scan + OMA +
    projection run under shard_map, and the single-request path becomes the
    B = 1 view of the sharded batch step.  `candidate_fn*` are ignored in
    that case (the sharded step owns candidate generation); `cfg.index`
    may name the sharded backend ("ivf_sharded", built through the same
    registry) or be None for the exact sharded scan; `sharded_kwargs`
    (e.g. `scan_chunk`) further configure the step.

    Online catalog mutation (DESIGN.md §10): `add_objects(vectors)` /
    `remove_objects(ids)` / `refresh()` admit and expire objects without a
    rebuild.  The first mutation flips serving to the mutable mode — eager
    candidate slabs against the live structures plus the jitted
    `make_mutable_step` tail — which never retraces under churn at fixed
    capacity and enforces the invalidation invariant (tombstoned rows
    carry zero y/x mass forever, so a removed object can neither be served
    nor re-fetched).  On a mesh with `index=None` mutation is fully
    supported (DESIGN.md §15): slab appends and tombstones route to the
    owning shard by global-id arithmetic, serving flips to
    `repro.core.distributed.make_mutable_step_sharded` (candidates + live-
    mask projection inside shard_map, bitwise the single-device mutable
    path on a 1-device mesh), and compaction keeps the slab mesh-aligned.
    Not supported with sharded *index backends* ("ivf_sharded") or with
    explicit `candidate_fn*` escape hatches."""

    def __init__(self, catalog: jax.Array, cfg: "AcaiConfig", candidate_fn=None,
                 candidate_fn_batched=None, seed=0, mesh=None,
                 sharded_kwargs: dict | None = None, c_f: float | None = None,
                 remote=None, resilience=None, answer_cache=None):
        from repro.index.base import resolve_spec
        from repro.serve.answer_cache import resolve_answer_cache_spec

        if not isinstance(cfg, AcaiConfig):
            # PolicySpec / flat-dict / name form (DESIGN.md §9): the one
            # config knob serialized by the experiment harness and dryrun
            # provenance records (both carry their c_f; a spec without one
            # needs the `c_f` kwarg).  Only the 'acai' policy builds an
            # AcaiCache; baselines go through policy_api.build_policy.
            from repro.core.costs import CostModel
            from repro.core.policy_api import (acai_config_from_spec,
                                               resolve_policy_spec)

            spec = resolve_policy_spec(cfg)
            if spec is None or spec.name != "acai":
                raise ValueError(
                    f"AcaiCache builds the 'acai' policy; got "
                    f"{getattr(spec, 'name', spec)!r} — use "
                    f"repro.core.policy_api.build_policy for baselines")
            cfg = acai_config_from_spec(
                spec, None if c_f is None else CostModel(c_f=c_f))
        elif c_f is not None:
            raise ValueError("c_f= only applies to the PolicySpec form "
                             "(AcaiConfig already carries its c_f)")
        # normalize every serialized spec form, incl. the reserved "exact"
        # (-> None), so provenance records round-trip into configs
        resolved = resolve_spec(cfg.index)
        if resolved is not cfg.index:
            cfg = dataclasses.replace(cfg, index=resolved)
        self.cfg = cfg
        self.catalog = catalog
        self.mesh = mesh
        self.index = None  # the spec-built index (None = exact/escape hatch)
        self._sharded_kwargs = dict(sharded_kwargs or {})
        self._bsteps: dict[int, Callable] = {}
        # mutable-catalog bookkeeping (DESIGN.md §10): the cache starts on
        # the static jitted path and flips to the mutable two-stage path
        # (eager candidates + jitted apply tail) on the first add/remove.
        self.valid = jnp.ones((catalog.shape[0],), bool)
        self._live = int(catalog.shape[0])
        self._n_slots = int(catalog.shape[0])
        self._mutated = False
        self._mut_fn: Callable | None = None
        self._mut_steps: dict[int, Callable] = {}
        explicit_fn = (candidate_fn is not None
                       or candidate_fn_batched is not None)
        self._custom_fn = explicit_fn
        if explicit_fn and cfg.index is not None:
            import warnings

            warnings.warn(
                "AcaiCache: cfg.index is set but "
                + ("a mesh was given — the sharded step ignores explicit "
                   "candidate fns and serves from the spec-built index"
                   if mesh is not None else
                   "explicit candidate_fn/candidate_fn_batched overrides "
                   "it — drop the kwargs or the spec"),
                DeprecationWarning, stacklevel=2)
        if mesh is not None:
            if cfg.index is not None:
                from repro.index.base import registered_backends

                if cfg.index.backend not in registered_backends(sharded=True):
                    # reject before paying the (possibly minutes-long) build
                    raise ValueError(
                        f"cfg.index backend {cfg.index.backend!r} is not a "
                        f"sharded layout; with mesh= use one of "
                        f"{registered_backends(sharded=True)} (or "
                        f"index=None for the exact sharded scan)")
                if "ivf" in self._sharded_kwargs:
                    import warnings

                    warnings.warn(
                        "AcaiCache: sharded_kwargs['ivf'] overrides "
                        "cfg.index — drop one of them",
                        DeprecationWarning, stacklevel=2)
                else:
                    built = build_index(cfg.index, catalog, mesh=mesh)
                    self.index = built
                    self._sharded_kwargs["ivf"] = built
            # built lazily on first serve_update: a B = 1 step only exists
            # on meshes whose batch axes have size 1 (serving meshes are
            # (1, P)); batched-only use of a (dp, P) mesh must not crash
            # here.
            self._step = None
        else:
            if candidate_fn_batched is None:
                if candidate_fn is None:
                    if cfg.index is not None:
                        from repro.index.candidates import \
                            index_candidate_fn_batched

                        self.index = build_index(cfg.index, catalog)
                        candidate_fn_batched = index_candidate_fn_batched(
                            self.index, catalog, cfg.c_remote, cfg.c_local,
                            h=cfg.h)
                    else:
                        candidate_fn_batched = exact_candidate_fn_batched(
                            catalog, cfg.c_remote, cfg.c_local
                        )
                else:
                    candidate_fn_batched = jax.vmap(candidate_fn,
                                                    in_axes=(0, None))
            self._fn_batched = candidate_fn_batched
            if candidate_fn is None:
                candidate_fn = per_request_view(candidate_fn_batched)
            self._step = jax.jit(make_step(cfg, candidate_fn))
        # answer-cache tier (DESIGN.md §13): wrap the spec-built index in
        # a CachedIndex and serve through the two-stage mutable path from
        # step 0 — the static jitted step queries the index inside its
        # trace, where nothing host-side can memoize, while the mutable
        # path's eager `index.query` is exactly the memoization point.
        self.answer_cache = None  # the CachedIndex wrapper when tier is on
        ac_spec = resolve_answer_cache_spec(answer_cache)
        if ac_spec is not None:
            from repro.serve.answer_cache import CachedIndex

            if mesh is not None:
                raise NotImplementedError(
                    "answer_cache= on a sharded mesh is not implemented "
                    "(the sharded step owns candidate generation) — use a "
                    "single-device cache")
            if self._custom_fn:
                raise ValueError(
                    "answer_cache= cannot front an explicit candidate_fn*: "
                    "the tier memoizes `Index.query` answers — drop the "
                    "escape hatch or the spec")
            if self.index is None:
                raise ValueError(
                    "answer_cache= fronts an index backend; set cfg.index "
                    "(IndexSpec('flat') gives the exact fused scan)")
            self.index = CachedIndex(self.index, ac_spec)
            self.answer_cache = self.index
            self._enter_mutable()
        self.state = init_state(catalog.shape[0], cfg, seed=seed)
        # resilient serving mode (DESIGN.md §11): None until a
        # RemoteBackend is attached; then serve_update(_batch) dispatch
        # through the retry/degrade ladder in repro.serve.resilience.
        self._res = None
        if remote is not None or resilience is not None:
            self.attach_remote(remote, resilience)

    def attach_remote(self, remote=None, resilience=None):
        """Switch serving to the resilient mode (DESIGN.md §11): requests
        first run their remote interaction (retries / hedging / deadline /
        circuit breaker) against `remote` — a `repro.serve.remote`
        backend — and failed requests are served through the graceful-
        degradation ladder.  With a healthy backend (or `remote=None`,
        the always-ok `OracleRemote`) every batch still takes the static
        jitted step, bitwise identical to the unattached cache.  Returns
        the `AcaiResilience` controller (counters, breaker log, reports).
        """
        from repro.serve.resilience import AcaiResilience

        if self.mesh is not None:
            raise NotImplementedError(
                "resilient serving on a sharded mesh is not implemented "
                "yet (ROADMAP open item) — attach the remote to a "
                "single-device cache")
        self._res = AcaiResilience(self, remote, resilience)
        return self._res

    def _sharded_step(self, batch: int) -> Callable:
        from repro.core.distributed import make_step_sharded

        return make_step_sharded(self.cfg, self.mesh, self.catalog, batch,
                                 **self._sharded_kwargs)

    def _mesh_model_size(self) -> int:
        from repro.core.distributed import _axis_size

        return _axis_size(self.mesh,
                          self._sharded_kwargs.get("model_axis", "model"))

    def _sharded_mutable_step(self, batch: int) -> Callable:
        from repro.core.distributed import make_mutable_step_sharded

        kw = {k: v for k, v in self._sharded_kwargs.items()
              if k in ("eta_scale", "model_axis", "batch_axes", "top_a")}
        return make_mutable_step_sharded(self.cfg, self.mesh, batch, **kw)

    def serve_update(self, r: jax.Array) -> StepMetrics:
        if self._res is not None or self._mutated:
            # B = 1 view of the resilient / mutable batch step
            m = self.serve_update_batch(r[None, :])
            return jax.tree_util.tree_map(lambda a: a[0], m)
        from repro.index.base import check_finite_queries

        check_finite_queries(r, "AcaiCache.serve_update")
        if self._step is None:  # lazy B = 1 view of the sharded step
            b1 = self._sharded_step(1)

            def _step1(state, rr):
                state, m = b1(state, rr[None, :])
                return state, jax.tree_util.tree_map(lambda a: a[0], m)

            self._step = jax.jit(_step1)
        self.state, metrics = self._step(self.state, r)
        return metrics

    def serve_update_batch(self, rs: jax.Array) -> StepMetrics:
        """Serve a request mini-batch (B, d): one OMA + rounding update for
        the whole batch, per-request StepMetrics (B,).  The jitted step is
        cached per batch size.  Once the catalog has mutated the step runs
        in two stages (eager candidate slab against the live structures +
        the jitted `make_mutable_step` tail).  With a RemoteBackend
        attached (`attach_remote`), the batch routes through the
        resilience ladder instead (DESIGN.md §11)."""
        from repro.index.base import check_finite_queries

        rs = jnp.atleast_2d(rs)
        check_finite_queries(rs, "AcaiCache.serve_update_batch")
        if self._res is not None:
            return self._res.serve_update_batch(rs)
        return self._serve_batch_direct(rs)

    def _serve_batch_direct(self, rs: jax.Array) -> StepMetrics:
        """The fault-oblivious serving step (also the all-ok fast path of
        the resilient mode, keeping fault-rate 0 bitwise identical)."""
        rs = jnp.atleast_2d(rs)
        b = rs.shape[0]
        if self._mutated:
            if self.mesh is not None:
                # sharded mutable serving: candidates, OMA and the live-
                # mask projection all run inside the shard_map step; the
                # (fixed-capacity) slab + mask are runtime args, so churn
                # reuses the cached jit per batch size
                step = self._mut_steps.get(b)
                if step is None:
                    step = self._sharded_mutable_step(b)
                    self._mut_steps[b] = step
                self.state, metrics = step(
                    self.state, rs, jnp.asarray(self.catalog, jnp.float32),
                    self.valid)
                return metrics
            ids, d, valid = self._mut_fn(rs, self.state.x)
            step = self._mut_steps.get(b)
            if step is None:
                step = make_mutable_step(self.cfg, b)
                self._mut_steps[b] = step
            self.state, metrics = step(self.state, ids, d, valid, self.valid)
            if self.answer_cache is not None:
                # book the answer-tier counters host-side: the hit mask of
                # the eager `CachedIndex.query` this batch just ran, plus
                # churn invalidations since the previous step (a per-batch
                # scalar like `fetched`, booked on the first request)
                mask, inval = self.answer_cache.cache.take_step_stats(b)
                hits = jnp.asarray(mask, jnp.int32)
                metrics = metrics._replace(
                    answer_hits=hits, answer_misses=1 - hits,
                    answer_invalidations=jnp.zeros(
                        (b,), jnp.int32).at[0].set(int(inval)))
            return metrics
        step = self._bsteps.get(b)
        if step is None:
            if self.mesh is not None:
                step = jax.jit(self._sharded_step(b))
            else:
                step = jax.jit(make_step_batched(self.cfg, self._fn_batched, b))
            self._bsteps[b] = step
        self.state, metrics = step(self.state, rs)
        return metrics

    # -- online catalog mutation (DESIGN.md §10) ----------------------------

    def _check_mutable_supported(self) -> None:
        """Reject mutation on configurations that cannot serve it — before
        anything is touched, so a failed call leaves the cache exactly as
        it was (still on the static jitted path)."""
        if self._mutated:
            return
        if self.mesh is not None:
            if self.index is not None or "ivf" in self._sharded_kwargs:
                raise NotImplementedError(
                    "online catalog mutation on a sharded index backend is "
                    "not implemented — the sharded mutable path serves "
                    "through the exact masked scan; build the mesh cache "
                    "with index=None (or rebuild the sharded index)")
            if self._sharded_kwargs.get("scan_chunk"):
                raise NotImplementedError(
                    "online catalog mutation on a sharded mesh serves "
                    "through the exact masked scan — drop "
                    "sharded_kwargs['scan_chunk']")
            cap = self.catalog.shape[0]
            n_model = self._mesh_model_size()
            if cap % n_model:
                raise ValueError(
                    f"slab capacity {cap} must divide by the mesh's "
                    f"{n_model} model shards before mutation")
        if self._custom_fn:
            raise ValueError(
                "AcaiCache was built with an explicit candidate_fn*: the "
                "cache cannot rebuild a custom generator after catalog "
                "mutation — drop the escape hatch or rebuild the cache")

    def _enter_mutable(self) -> None:
        """Flip from the static jitted path to the mutable serving mode
        after a successful first mutation (the static path's traced
        constants would serve the pre-mutation catalog forever)."""
        if self._mutated:
            return
        if self.mesh is not None:
            # the sharded mutable step owns candidate generation inside
            # shard_map (catalog + liveness as runtime args); there is no
            # eager host-side candidate stage to build
            self._mut_fn = None
            self._mutated = True
            return
        if self.index is not None:
            from repro.index.candidates import mutable_index_candidate_fn

            self._mut_fn = mutable_index_candidate_fn(
                self.index, self.cfg.c_remote, self.cfg.c_local,
                h=self.cfg.h)
        else:

            def _exact(rs, x):
                return exact_mutable_candidates(
                    rs, x, self.catalog, self.valid, self.cfg.c_remote,
                    self.cfg.c_local)

            self._mut_fn = _exact
        self._mutated = True

    def _sync_capacity(self, new_ids) -> None:
        """Grow the OMA state to the (possibly doubled) slab capacity and
        admit the new rows at the uniform prior y = h / n_live (Alg. 1's
        y_1 for the object, fresh-start semantics; the next projection
        renormalises the small capacity excess)."""
        from repro.index.base import _flat_set, pad_ids, run_device

        cap = self.catalog.shape[0]
        y, x = self.state.y, self.state.x
        if y.shape[0] != cap:
            y = jnp.pad(y, (0, cap - y.shape[0]))
            x = jnp.pad(x, (0, cap - x.shape[0]))
        prior = min(1.0, self.cfg.h / max(self._live, 1))
        # donated width-padded scatter (padded lanes carry an OOB index
        # and are dropped): fixed shapes, no per-batch-size retrace, and
        # the state buffer mutates in place on device
        y = run_device(_flat_set, y, pad_ids(new_ids, cap),
                       jnp.float32(prior))
        self.state = CacheState(y, x, self.state.t, self.state.key)

    def add_objects(self, vectors) -> "np.ndarray":
        """Admit new catalog objects online: append to the shared slab
        (and the remote index's structures, when one is configured),
        grow the OMA state, and seed the new rows with the uniform prior.
        Returns their (monotonic, never-recycled) row ids."""
        self._check_mutable_supported()
        vectors = jnp.atleast_2d(jnp.asarray(vectors, jnp.float32))
        if self.index is not None:
            ids = self.index.add(vectors)
            self.catalog = self.index.embeddings
            self.valid = self.index.valid
        else:
            from repro.index.base import slab_append

            self.catalog = jnp.asarray(self.catalog, jnp.float32)
            if self.mesh is not None:
                # owner-shard routing (DESIGN.md §15): the append splits at
                # shard-block boundaries and each run's donated write goes
                # to the owning shard's slice; growth stays mesh-aligned
                from repro.core.distributed import sharded_slab_append

                self.catalog, self.valid, ids = sharded_slab_append(
                    self.catalog, self.valid, self._n_slots, vectors,
                    self._mesh_model_size())
            else:
                self.catalog, self.valid, ids = slab_append(
                    self.catalog, self.valid, self._n_slots, vectors)
        self._n_slots += len(ids)
        self._live += len(ids)
        self._sync_capacity(ids)
        self._enter_mutable()
        return ids

    def remove_objects(self, ids) -> None:
        """Drop catalog objects online: tombstone the rows and zero their
        fractional + physical cache mass (the invalidation invariant — a
        removed object is never served, never fetched, and frees its cache
        slot immediately; `make_mutable_step` keeps the rows at zero)."""
        self._check_mutable_supported()
        import numpy as np

        from repro.index.base import (_flat_set, _mask_clear, _mask_gather,
                                      pad_ids, run_device)

        ids = np.atleast_1d(np.asarray(ids, np.int32))
        if self.index is not None:
            self.index.remove(ids)
            self.valid = self.index.valid
        else:
            if len(ids) and (ids.min() < 0 or ids.max() >= self._n_slots):
                raise ValueError(
                    f"remove_objects: ids must be assigned rows in "
                    f"[0, {self._n_slots})")
            if len(np.unique(ids)) != len(ids):
                raise ValueError("remove_objects: duplicate ids in one "
                                 "batch")
            # width-padded aliveness gather + donated tombstone write
            # (fixed shapes — no per-batch-size retrace under churn)
            cap = self.valid.shape[0]
            alive = np.asarray(run_device(
                _mask_gather, self.valid, pad_ids(ids, cap)))[:len(ids)]
            if not alive.all():
                raise ValueError(
                    f"remove_objects: rows {ids[~alive].tolist()} are "
                    f"already dead")
            self.catalog = jnp.asarray(self.catalog, jnp.float32)
            if self.mesh is not None:
                # tombstone writes routed to the owning shard by global-id
                # arithmetic (one donated scatter per shard touched; the
                # P = 1 grouping is the single-device call, bitwise)
                from repro.core.distributed import route_ids_by_owner

                for _, gids in route_ids_by_owner(
                        ids, cap, self._mesh_model_size()):
                    self.valid = run_device(_mask_clear, self.valid,
                                            pad_ids(gids, cap))
            else:
                self.valid = run_device(_mask_clear, self.valid,
                                        pad_ids(ids, cap))
        self._live -= len(ids)
        self._enter_mutable()
        # zero the removed rows' fractional + physical mass via donated
        # padded scatters (the invalidation invariant), routed per owning
        # shard on a mesh
        scap = self.state.y.shape[0]
        if self.mesh is not None:
            from repro.core.distributed import route_ids_by_owner

            groups = [g for _, g in route_ids_by_owner(
                ids, scap, self._mesh_model_size())]
        else:
            groups = [ids]
        y, x = self.state.y, self.state.x
        for gids in groups:
            jid = pad_ids(gids, scap)
            y = run_device(_flat_set, y, jid, jnp.float32(0.0))
            x = run_device(_flat_set, x, jid, jnp.float32(0.0))
        self.state = CacheState(y, x, self.state.t, self.state.key)

    def refresh(self) -> None:
        """Rebuild the remote index's structures over the live rows
        (tombstone compaction / quantizer re-train; see Index.refresh).
        A no-op for exact candidates, whose masked scan never drifts."""
        if self.index is not None and self._mutated:
            self.index.refresh()

    def refresh_start(self) -> None:
        """Phase 1 of the double-buffered refresh (DESIGN.md §14): build
        the shadow structures while the stale ones keep serving."""
        if self.index is not None and self._mutated:
            self.index.refresh_start()

    def refresh_swap(self) -> None:
        """Phase 2: install the pending shadow — the only serving-visible
        stall, a few attribute swaps."""
        if self.index is not None and self._mutated:
            self.index.refresh_swap()

    def compact(self) -> "np.ndarray":
        """Epoch compaction (DESIGN.md §14): drop tombstoned rows, shrink
        the slab back to the live set (plus one write window of headroom),
        and renumber the survivors in ascending-id order.  The OMA y/x
        state rows move with their objects — pure permutation, no
        arithmetic.  Returns the (old_capacity,) int32 remap (new row id,
        or -1 for dead rows); callers own pushing it to every other id
        holder (payload stores, oracles, answer caches)."""
        self._check_mutable_supported()
        import numpy as np

        from repro.index.base import MIN_WRITE, grow_capacity

        old_cap = self.catalog.shape[0]
        if self.index is not None:
            remap = self.index.compact()
            self.catalog = self.index.embeddings
            self.valid = self.index.valid
        else:
            live = np.nonzero(np.asarray(self.valid))[0]
            n_live = live.size
            remap = np.full(old_cap, -1, np.int32)
            remap[live] = np.arange(n_live, dtype=np.int32)
            cap = grow_capacity(0, n_live + MIN_WRITE, 1)
            if self.mesh is not None:
                # keep the compacted slab mesh-aligned so owner-shard
                # arithmetic survives (a no-op for power-of-two meshes:
                # the doubling schedule already lands on a multiple)
                cap += (-cap) % self._mesh_model_size()
            emb_live = jnp.asarray(self.catalog,
                                   jnp.float32)[jnp.asarray(live)]
            self.catalog = jnp.pad(emb_live, ((0, cap - n_live), (0, 0)))
            self.valid = jnp.pad(jnp.ones((n_live,), bool),
                                 (0, cap - n_live))
        self._n_slots = self._live
        cap = self.catalog.shape[0]
        old_y = np.asarray(self.state.y)
        old_x = np.asarray(self.state.x)
        y = np.zeros(cap, old_y.dtype)
        x = np.zeros(cap, old_x.dtype)
        src = np.nonzero(remap >= 0)[0]
        y[remap[src]] = old_y[src]
        x[remap[src]] = old_x[src]
        self.state = CacheState(jnp.asarray(y), jnp.asarray(x),
                                self.state.t, self.state.key)
        self._enter_mutable()
        return remap

    @property
    def live_count(self) -> int:
        """Live (non-tombstoned) catalog objects."""
        return self._live

    @property
    def cached_ids(self):
        return jnp.nonzero(self.state.x > 0.5)[0]

    def normalized_gain(self, total_gain: float, t: int) -> float:
        """NAG of Eq. (11)."""
        return float(total_gain) / (self.cfg.k * self.cfg.c_f * max(t, 1))
