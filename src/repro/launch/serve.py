"""Serving driver: continuous-batching decode with the AÇAI semantic cache
in front (the paper's edge-inference deployment).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
      --requests 40
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, SMOKE_ARCHS
from repro.models import init_params
from repro.serve import SemanticCachedLM, ServeEngine, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-tokens", type=int, default=8)
    ap.add_argument("--catalog", type=int, default=512)
    ap.add_argument("--cache-size", type=int, default=64)
    args = ap.parse_args()

    cfg = (SMOKE_ARCHS if args.smoke else ARCHS)[args.arch]
    if not cfg.has_decode:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode serving")
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    rng = np.random.default_rng(0)

    # --- continuous batching engine -------------------------------------
    engine = ServeEngine(params, cfg, batch=args.batch,
                         s_max=args.prompt_len + args.max_tokens + 8)
    prompts = [jnp.asarray(rng.integers(0, cfg.vocab, args.prompt_len),
                           jnp.int32) for _ in range(args.requests)]
    t0 = time.time()
    for i, p in enumerate(prompts):
        engine.submit(i, p, args.max_tokens)
    steps = 0
    while engine.step():
        steps += 1
    dt = time.time() - t0
    total_tokens = sum(len(t) for t in engine.done.values())
    print(f"continuous batching: {len(engine.done)} requests, "
          f"{total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens / dt:.1f} tok/s), {steps} engine steps")

    # --- semantic cache tier ---------------------------------------------
    catalog = jnp.asarray(rng.normal(size=(args.catalog, cfg.d_model)),
                          jnp.float32)
    catalog = catalog / jnp.linalg.norm(catalog, axis=1, keepdims=True)
    payloads = [f"cached-result-{i}" for i in range(args.catalog)]

    def gen_fn(prompt_tokens):
        return generate(params, cfg, prompt_tokens[None], steps=4)

    lm = SemanticCachedLM(params, cfg, catalog, payloads, gen_fn,
                          h=args.cache_size, k=4)
    for i in range(args.requests):
        toks = jnp.asarray(rng.integers(0, cfg.vocab, args.prompt_len),
                           jnp.int32)
        lm.query(toks)
    s = lm.stats
    print(f"semantic cache: {s.requests} requests, "
          f"{s.served_local}/{s.requests * lm.cache.cfg.k} objects local, "
          f"{s.generated} generations, NAG={lm.nag:.3f}")


if __name__ == "__main__":
    main()
