"""Synthetic data pipeline: deterministic, shard-aware, host-prefetched.

At multi-host scale each process generates only its shard of the global
batch (process_index-keyed PRNG streams) and `device_put`s it with the
batch sharding, so the pipeline is a drop-in for a real tokenized corpus
loader.  A background thread keeps `prefetch` batches ahead of the step
loop (CPU-side pipelining — the host analogue of overlapping input copy
with compute).
"""

from __future__ import annotations

import queue
import threading

import jax
import numpy as np

from repro.configs.shapes import ShapeSpec
from repro.models.config import ModelConfig
from repro.train.batching import batch_shapes


class SyntheticDataset:
    """Zipf-distributed token streams (vocab-shaped, deterministic)."""

    def __init__(self, cfg: ModelConfig, shape: ShapeSpec, seed: int = 0,
                 process_index: int = 0, process_count: int = 1):
        self.cfg, self.shape = cfg, shape
        self.seed = seed
        self.process_index, self.process_count = process_index, process_count
        self.shapes = batch_shapes(cfg, shape, "train")

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng(
            (self.seed, step, self.process_index))
        out = {}
        for k, (sh, dt) in self.shapes.items():
            local = (sh[0] // self.process_count,) + tuple(sh[1:])
            if k == "positions3":
                local = (3, sh[1] // self.process_count) + tuple(sh[2:])
            if np.dtype(dt) == np.int32:
                hi = self.cfg.vocab if k in ("tokens", "labels") else 4
                # zipf-ish skew, clipped into the vocab
                z = rng.zipf(1.3, size=local) - 1
                out[k] = np.asarray(np.minimum(z, hi - 1), np.int32)
            elif k == "loss_mask":
                out[k] = np.ones(local, np.float32)
            else:
                out[k] = rng.normal(0, 1, local).astype(np.dtype(dt).name
                                                        if dt != "bfloat16"
                                                        else np.float32)
        return out


class Prefetcher:
    def __init__(self, dataset: SyntheticDataset, prefetch: int = 2,
                 start_step: int = 0, put_fn=None):
        self.dataset = dataset
        self.q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self.put_fn = put_fn or (lambda b: b)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        while not self._stop.is_set():
            try:
                self.q.put((self._step, self.put_fn(self.dataset.batch(self._step))),
                           timeout=0.2)
                self._step += 1
            except queue.Full:
                continue

    def next(self):
        return self.q.get()

    def stop(self):
        self._stop.set()
        self._thread.join(timeout=2)
