"""Churn replay driver: a trace + an insert/expire schedule, one policy.

The mutable-catalog harness (DESIGN.md §10): `replay_with_churn` drives
any `CachePolicy` (or a bare `AcaiCache`) through a request trace while a
`rolling_catalog_events`-style schedule mutates the catalog between
mini-batch steps — insertions through the policy's `add_objects`,
expiries through `remove_objects`, plus an optional periodic `refresh()`
cadence.  Mutation, refresh, and step wall times are booked separately so
the churn bench can show the refresh-amortization trade-off rather than
one blended number.

Row-id alignment: the policy is built on the trace catalog's warm prefix
`catalog[:n0]` and the schedule inserts rows in ascending order, so the
policy's monotonic id assignment reproduces the trace's row ids exactly —
`replay_with_churn` asserts it (a mismatch means the caller built the
policy on the wrong catalog slice).

At churn_rate = 0 the schedule is empty: the policy never leaves its
static jitted path, and an AÇAI replay is bit-consistent with
`make_replay_batched` on the same trace (pinned by
tests/test_mutable_index.py).
"""

from __future__ import annotations

import time
from typing import Sequence

import numpy as np


def warm_size(n: int, warm: float) -> int:
    """Live-window population of a rolling_catalog trace (shared rounding
    with `trace.rolling_catalog_events`)."""
    return max(int(round(warm * n)), 1)


def replay_with_churn(pol, catalog: np.ndarray, reqs: np.ndarray,
                      events: Sequence, *, batch: int = 8,
                      refresh_every: int = 0) -> dict:
    """Replay `reqs` through `pol` while `events` mutate the catalog.

    Args:
      pol: a CachePolicy (or AcaiCache) exposing `serve_update_batch`,
        `add_objects`, `remove_objects` and `refresh`, built over the
        trace catalog's warm prefix.
      catalog: the full (N, d) object universe of the trace — insert
        events read their embeddings here.
      reqs: (T, d) request stream; the tail not filling a mini-batch is
        dropped (the make_replay_batched convention).
      events: [(step, insert_ids, remove_ids), ...] with ascending steps
        (e.g. `trace.rolling_catalog_events(**spec.params)`); an event
        fires before the mini-batch containing request `step`.  Events
        landing in the truncated trace tail are applied after the last
        mini-batch, so the catalog always ends in the schedule's final
        state.
      batch: requests per mini-batch step.
      refresh_every: call `pol.refresh()` every that-many *requests*
        (0 = never) — the amortization knob: frequent refresh restores
        index recall but pays rebuild wall time.

    Returns:
      dict of per-request metric arrays (gain, cost, served_local, hit,
      fetched, occupancy) plus `p50_step_s` (serving steps only),
      `mutation_s` / `refresh_s` (total wall spent mutating/rebuilding),
      `events_applied`, `requests`.
    """
    reqs = np.asarray(reqs)
    t = reqs.shape[0]
    tt = (t // batch) * batch
    if tt == 0:
        raise ValueError(
            f"trace of {t} requests is shorter than one mini-batch "
            f"(batch={batch})")
    pending = sorted(events, key=lambda ev: ev[0])
    out = {k: [] for k in ("gain", "cost", "served_local", "fetched",
                           "occupancy")}
    times, mutation_s, refresh_s, applied = [], 0.0, 0.0, 0
    next_refresh = refresh_every
    ev_i = 0
    for s in range(0, tt, batch):
        while ev_i < len(pending) and pending[ev_i][0] < s + batch:
            _, ins, rem = pending[ev_i]
            t0 = time.time()
            if len(ins):
                got = np.asarray(pol.add_objects(catalog[np.asarray(ins)]))
                assert (got == np.asarray(ins)).all(), (
                    f"row-id misalignment: schedule inserts {ins}, policy "
                    f"assigned {got} — was the policy built on "
                    f"catalog[:n_warm]?")
            if len(rem):
                pol.remove_objects(rem)
            mutation_s += time.time() - t0
            applied += 1
            ev_i += 1
        if refresh_every and s >= next_refresh:
            t0 = time.time()
            pol.refresh()
            refresh_s += time.time() - t0
            next_refresh += refresh_every
        t0 = time.time()
        m = pol.serve_update_batch(reqs[s:s + batch])
        times.append(time.time() - t0)
        out["gain"].append(np.asarray(m.gain_int, np.float64))
        out["cost"].append(np.asarray(m.cost, np.float64))
        out["served_local"].append(np.asarray(m.served_local))
        out["fetched"].append(np.asarray(m.fetched))
        out["occupancy"].append(np.asarray(m.occupancy, np.float64))
    # drain events landing in the truncated trace tail (t % batch != 0)
    # so the final catalog state always matches the schedule's end state
    # and events_applied == len(events) unconditionally
    while ev_i < len(pending):
        _, ins, rem = pending[ev_i]
        t0 = time.time()
        if len(ins):
            pol.add_objects(catalog[np.asarray(ins)])
        if len(rem):
            pol.remove_objects(rem)
        mutation_s += time.time() - t0
        applied += 1
        ev_i += 1
    res = {k: np.concatenate(v) for k, v in out.items()}
    res["hit"] = res["served_local"] > 0
    res["p50_step_s"] = float(np.percentile(times, 50)) if times else 0.0
    res["mutation_s"] = mutation_s
    res["refresh_s"] = refresh_s
    res["events_applied"] = applied
    res["requests"] = int(tt)
    return res
