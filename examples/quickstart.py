"""Quickstart: AÇAI similarity caching on a synthetic SIFT-like trace.

Builds a catalog, calibrates the fetching cost the paper's way (average
distance of the 50th neighbour), replays a request trace through AÇAI and
through the classical baselines, and prints the normalised average gain
(Eq. 11) — reproducing the paper's headline result (Fig. 1) in miniature.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import baselines as B
from repro.core import oma, policy, trace
from repro.core.costs import calibrate_fetch_cost


def main():
    n, t, h, k = 4000, 4000, 150, 10
    catalog_np, requests, _ = trace.sift_like(n=n, d=32, t=t, seed=0)
    catalog = jnp.array(catalog_np)
    c_f = float(calibrate_fetch_cost(catalog, kth=50))
    print(f"catalog N={n}, trace T={t}, cache h={h}, k={k}, c_f={c_f:.3f}\n")

    # --- AÇAI -------------------------------------------------------------
    cfg = policy.AcaiConfig(h=h, k=k, c_f=c_f, c_remote=64, c_local=16,
                            oma=oma.OMAConfig(eta=0.05 / c_f))
    replay = policy.make_replay(
        cfg, policy.exact_candidate_fn(catalog, cfg.c_remote, cfg.c_local))
    state, m = replay(policy.init_state(n, cfg), jnp.array(requests))
    nag_acai = B.nag(np.array(m.gain_int), k, c_f)
    print(f"{'ACAI':10s} NAG={nag_acai[-1]:.4f}  "
          f"(local answers/req: {np.array(m.served_local)[-500:].mean():.1f}/{k})")

    # --- baselines ---------------------------------------------------------
    oracle = B.ServerOracle(catalog_np, requests, kmax=64)
    for name, cls in B.POLICIES.items():
        kwargs = dict(h=h, k=k, c_f=c_f)
        if name in ("SIM-LRU", "CLS-LRU", "RND-LRU"):
            kwargs.update(k_prime=2 * k, c_theta=1.5 * c_f)
        metrics = B.run_policy(cls(catalog_np, oracle, **kwargs), requests)
        print(f"{name:10s} NAG={B.nag(metrics['gain'], k, c_f)[-1]:.4f}")

    print("\nNAG trajectory (ACAI):",
          " ".join(f"{nag_acai[i]:.3f}" for i in
                   [99, 499, 999, 1999, t - 1]))


if __name__ == "__main__":
    main()
