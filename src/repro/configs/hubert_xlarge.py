"""hubert-xlarge [audio] — encoder-only (arXiv:2106.07447).

48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 (masked-prediction cluster
targets).  The waveform/conv frontend is a STUB: input_specs() provides
precomputed frame embeddings (B, S, d_model).  Bidirectional attention,
no decode shapes.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    causal=False,
    pos_emb="none",
    modality="audio",
    fsdp=True,
)

SMOKE = ModelConfig(
    name="hubert-xlarge-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=64,
    causal=False, pos_emb="none", modality="audio",
)
