"""Product quantization codec + IVF-PQ index (the paper's remote-catalog
index: ~30 bytes/object à la FAISS IVFPQ, Sec. III).

The ADC scan runs through repro.kernels.ops.pq_adc — the one-hot-matmul TPU
adaptation of the GPU shared-memory gather (DESIGN.md §3).  An optional
exact re-rank of the top candidates (refine factor) recovers recall, which
is standard FAISS practice and what AÇAI needs to estimate true server-side
dissimilarity costs.

Mutable catalog (DESIGN.md §10): `add` is encode-on-insert — new rows are
PQ-coded with the *frozen* codebooks and binned by the stale coarse
quantizer (FAISS add-time semantics); `remove` tombstones (stale list
entries and codes are masked at query time); `refresh` re-trains both the
coarse quantizer and the PQ codebooks over the live rows and re-encodes
them.  Codebook drift between refreshes costs ADC accuracy on inserted
rows — the refine re-rank absorbs most of it, and the churn bench
quantifies the rest.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.index.base import (MutableRows, _rows_write, arrays_bytes,
                              check_finite_queries, pad_rows, run_device,
                              track_jit)
from repro.index.ivf import (_assign_lists, build_invlists,
                             invlist_device_append)
from repro.index.kmeans import kmeans
from repro.kernels import ops


@track_jit("pq_encode")
@jax.jit
def _pq_encode(data: jax.Array, codebooks: jax.Array) -> jax.Array:
    """(n, d) x (m, ksub, dsub) codebooks -> (n, m) int32 codes.

    Codebooks are a *runtime* argument (not a static self): refresh
    re-trains them without leaving stale compiled entries pinned in the
    jit cache — the long-running churn regime rebuilds codecs repeatedly.
    """
    n, d = data.shape
    m, _, dsub = codebooks.shape
    sub = data.reshape(n, m, dsub).transpose(1, 0, 2)
    d2 = jax.vmap(ops.pairwise_l2_xla)(sub, codebooks)   # (m, n, ksub)
    return jnp.argmin(d2, axis=-1).T.astype(jnp.int32)    # (n, m)


@jax.jit
def _pq_decode(codes: jax.Array, codebooks: jax.Array) -> jax.Array:
    gathered = jax.vmap(lambda cb, c: cb[c], in_axes=(0, 1))(
        codebooks, codes
    )  # (m, n, dsub)
    return gathered.transpose(1, 0, 2).reshape(codes.shape[0], -1)


def _pq_adc_lut(q: jax.Array, codebooks: jax.Array) -> jax.Array:
    """(B, d) -> (B, m, ksub) per-subspace distance tables (traced inside
    _ivfpq_query; codebooks ride as a runtime argument)."""
    b = q.shape[0]
    m, _, dsub = codebooks.shape
    sub = q.reshape(b, m, dsub).transpose(1, 0, 2)        # (m, B, dsub)
    lut = jax.vmap(ops.pairwise_l2_xla)(sub, codebooks)   # (m, B, ksub)
    return lut.transpose(1, 0, 2)


_pq_adc_lut_jit = jax.jit(_pq_adc_lut)


class PQCodec:
    """M sub-spaces x 256-centroid codebooks."""

    def __init__(self, data: jax.Array, m: int = 8, nbits: int = 8,
                 train_iters: int = 12, seed: int = 0):
        n, d = data.shape
        assert d % m == 0, (d, m)
        self.m, self.dsub, self.ksub = m, d // m, 2 ** nbits
        sub = jnp.asarray(data, jnp.float32).reshape(n, m, self.dsub)
        keys = jax.random.split(jax.random.PRNGKey(seed), m)
        ksub = min(self.ksub, n)
        cents, _ = jax.vmap(lambda k, x: kmeans(k, x, ksub, train_iters))(
            keys, sub.transpose(1, 0, 2)
        )
        if ksub < self.ksub:  # pad tiny training sets
            pad = jnp.repeat(cents[:, :1], self.ksub - ksub, axis=1)
            cents = jnp.concatenate([cents, pad], axis=1)
        self.codebooks = cents  # (m, ksub, dsub)

    def encode(self, data: jax.Array) -> jax.Array:
        return _pq_encode(data, self.codebooks)

    def decode(self, codes: jax.Array) -> jax.Array:
        return _pq_decode(codes, self.codebooks)

    def adc_lut(self, q: jax.Array) -> jax.Array:
        """(B, d) -> (B, m, ksub) per-subspace distance tables."""
        return _pq_adc_lut_jit(q, self.codebooks)

    def __hash__(self):
        return id(self)

    def __eq__(self, other):
        return self is other


@track_jit("pq_query")
@partial(jax.jit, static_argnames=("k", "nprobe", "refine", "masked"))
def _ivfpq_query(q, emb, centroids, invlists, codes, codebooks, valid,
                 k: int, nprobe: int, refine: int, masked: bool):
    q = jnp.atleast_2d(q)
    b = q.shape[0]
    dc = ops.pairwise_l2_xla(q, centroids)
    _, probe = jax.lax.top_k(-dc, nprobe)
    cand = invlists[probe].reshape(b, -1)               # (B, P)
    if masked:  # tombstoned rows -> the -1 invalid-slot convention
        cand = jnp.where(
            (cand >= 0) & valid[jnp.clip(cand, 0, emb.shape[0] - 1)],
            cand, -1)
    valid_slot = cand >= 0
    safe = jnp.clip(cand, 0, None)

    lut = _pq_adc_lut(q, codebooks)                      # (B, m, ksub)
    gathered = codes[safe]                               # (B, P, m)
    # per-query ADC over its own candidate rows
    d_adc = jax.vmap(lambda l, c: ops.pq_adc(l[None], c)[0])(lut, gathered)
    d_adc = jnp.where(valid_slot, d_adc, jnp.inf)

    if refine and refine > 1:
        r = min(refine * k, d_adc.shape[1])
        neg, pos = jax.lax.top_k(-d_adc, r)              # approx top-r
        rid = jnp.take_along_axis(cand, pos, axis=1)
        rid = jnp.where(jnp.isfinite(neg), rid, -1)
        # exact re-rank through the fused gather+L2+top-k scan (cand was
        # already validity-masked above)
        return ops.ivf_scan_auto(q, emb, rid, k)

    neg, pos = jax.lax.top_k(-d_adc, k)
    ids = jnp.take_along_axis(cand, pos, axis=1)
    return -neg, jnp.where(jnp.isfinite(neg), ids, -1)


class IVFPQIndex(MutableRows):
    """Coarse IVF + PQ-coded residual-free storage + optional exact refine."""

    # answer-cache capability flags (repro.serve.answer_cache): the ADC
    # shortlist is rank-R by *approximate* distance, so an add/remove can
    # move the shortlist boundary and change refined answers that never
    # contained the mutated rows — the cache must flush, not radius-check.
    answer_unstable_add = True
    answer_unstable_remove = True

    def __init__(self, embeddings, nlist: int = 64, nprobe: int = 8,
                 m: int = 8, refine: int = 4, seed: int = 0):
        self._init_rows(embeddings)
        self.nlist, self.nprobe, self.refine = nlist, nprobe, refine
        self.m, self.seed = m, seed
        # with refine the final top-k is exactly re-ranked; without it the
        # returned distances are ADC approximations (re-rank downstream)
        self.exact_distances = bool(refine and refine > 1)
        self._build_structures()

    def _compute_structures(self):
        """(Re-)train quantizer + codebooks and (re-)encode the live rows;
        ids are stable (local build ids remap to slab rows).  Pure — the
        live structures keep serving until `_install_structures`."""
        live = self.live_rows()
        n_live = len(live)
        emb_live = (self.embeddings if n_live == self.capacity
                    else self.embeddings[jnp.asarray(live)])
        nlist = min(self.nlist, max(n_live, 1))
        key = jax.random.PRNGKey(self.seed)
        centroids, assign = kmeans(key, emb_live, nlist)
        table = build_invlists(np.asarray(assign), nlist)
        if n_live != self.capacity:
            table = np.where(table >= 0, live[np.clip(table, 0, None)], -1)
        cursor = (table >= 0).sum(axis=1).astype(np.int32)
        codec = PQCodec(emb_live, m=self.m, seed=self.seed + 1)
        codes_live = codec.encode(emb_live)              # (n_live, m)
        codes = np.zeros((self.capacity, self.m), np.int32)
        codes[live] = np.asarray(codes_live)
        return (centroids, jnp.asarray(table, jnp.int32), cursor, codec,
                jnp.asarray(codes))

    def _install_structures(self, structures) -> None:
        (self.centroids, self.invlists, self._cursor, self.codec,
         self.codes) = structures

    # -- mutation -----------------------------------------------------------

    def add(self, vectors) -> np.ndarray:
        """Encode-on-insert: PQ-code the new rows with the frozen codebooks
        and append to the (stale-centroid) inverted lists.

        Device-resident fast path: the incoming batch is width-padded once,
        encoded and assigned by tracked jits, the codes land in the
        (cap, m) slab via a donated contiguous row write (appended ids are
        consecutive), and the list ids via a donated flat scatter — no
        numpy masters, no full re-uploads."""
        vec_np = np.asarray(vectors, np.float32)
        ids = self._append_rows(vec_np)
        b = ids.shape[0]
        if self.codes.shape[0] < self.capacity:  # slab grew (rare)
            self.codes = jnp.pad(
                self.codes, ((0, self.capacity - self.codes.shape[0]),
                             (0, 0)))
        vecs = pad_rows(vec_np)
        codes_new = run_device(_pq_encode, vecs, self.codec.codebooks)
        # appended ids are consecutive and the slab keeps a full write
        # window of headroom, so the padded lanes land on unused slots
        self.codes = run_device(_rows_write, self.codes, codes_new,
                                np.int32(ids[0]))
        assign = np.asarray(run_device(
            _assign_lists, vecs, self.centroids))[:b]
        self.invlists = invlist_device_append(self.invlists, self._cursor,
                                              assign, ids)
        return ids

    # -- queries ------------------------------------------------------------

    def memory_bytes(self) -> int:
        """Everything resident at query time, like every other backend:
        the float32 slab (the refine re-rank gathers from it) plus the PQ
        structures.  The paper's ~30 B/object compressed accounting is
        `compressed_bytes()`."""
        return arrays_bytes(self.embeddings, self.codes,
                            self.codec.codebooks, self.centroids,
                            self.invlists, self.valid)

    def compressed_bytes(self) -> int:
        """PQ-only footprint (codes + codebooks + coarse layer): what a
        deployment that drops the float32 slab (refine=0, re-rank
        downstream) would hold — the paper's ~30 B/object figure."""
        return arrays_bytes(self.codes, self.codec.codebooks,
                            self.centroids, self.invlists)

    def query(self, q: jax.Array, k: int):
        check_finite_queries(q, "IVFPQIndex.query")
        return _ivfpq_query(q, self.embeddings, self.centroids,
                            self.invlists, self.codes,
                            self.codec.codebooks, self.valid, k,
                            min(self.nprobe, self.centroids.shape[0]),
                            self.refine, masked=self._live != self._n_slots)

    def __hash__(self):
        return id(self)

    def __eq__(self, other):
        return self is other
