"""Corollary IV.1.1: AÇAI as an offline (1-1/e)-approximation solver.

Run OMA over a trace, average the fractional iterates y_t, round the
average with DepRound, and compare the static allocation's gain against
(a) the popularity heuristic and (b) AÇAI's own online gain — the averaged
iterate should be a near-(1-1/e)-optimal *static* configuration.

  PYTHONPATH=src python examples/offline_allocation.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import gain as G
from repro.core import oma, policy, rounding, trace
from repro.core.costs import calibrate_fetch_cost


def static_gain(catalog, x, requests, k, c_f):
    vals = []
    for r in requests[::10]:
        d = jnp.sum((catalog - jnp.array(r)[None, :]) ** 2, -1)
        vals.append(float(G.gain_value(d, jnp.array(x), k, c_f)))
    return float(np.mean(vals))


def main():
    n, t, h, k = 3000, 4000, 100, 10
    catalog_np, requests, _ = trace.sift_like(n=n, d=32, t=t, seed=0)
    catalog = jnp.array(catalog_np)
    c_f = float(calibrate_fetch_cost(catalog, kth=50))

    cfg = policy.AcaiConfig(h=h, k=k, c_f=c_f,
                            oma=oma.OMAConfig(eta=0.05 / c_f))
    fn = policy.exact_candidate_fn(catalog, cfg.c_remote, cfg.c_local)
    step = policy.make_step(cfg, fn)

    # replay while accumulating the average fractional state y_bar
    @jax.jit
    def replay(state, reqs):
        def body(carry, r):
            st, ysum = carry
            st, m = step(st, r)
            return (st, ysum + st.y), m.gain_int
        (st, ysum), gains = jax.lax.scan(
            body, (state, jnp.zeros_like(state.y)), reqs)
        return st, ysum / reqs.shape[0], gains

    state = policy.init_state(n, cfg)
    state, y_bar, gains = replay(state, jnp.array(requests))
    online_avg = float(np.mean(np.array(gains)))

    # round the averaged iterate -> static allocation (Corollary IV.1.1)
    x_bar = rounding.depround(jax.random.PRNGKey(1), y_bar)
    g_acai = static_gain(catalog, x_bar, requests, k, c_f)

    # popularity heuristic comparator
    near = np.array(jnp.argmin(
        jnp.sum((catalog[None, ::1] - jnp.array(requests[:500, None])) ** 2,
                -1), axis=1))
    top = np.bincount(near, minlength=n).argsort()[::-1][:h]
    x_pop = np.zeros(n, np.float32)
    x_pop[top] = 1.0
    g_pop = static_gain(catalog, jnp.array(x_pop), requests, k, c_f)

    norm = k * c_f
    print(f"static allocation from averaged OMA iterate: {g_acai / norm:.4f}")
    print(f"static popularity-top-h heuristic:           {g_pop / norm:.4f}")
    print(f"AÇAI online average gain:                    {online_avg / norm:.4f}")
    print(f"(1-1/e) reference factor:                    {1 - 1 / np.e:.4f}")


if __name__ == "__main__":
    main()
