"""Answer-cache tier: serve repeated queries without touching the scan.

The head-heavy regime (DESIGN.md §13): a Zipf-skewed trace repeats its
hot queries over and over, and without memoization every repeat pays a
full fused index scan.  This example wraps the index in an
`AnswerCacheSpec`, replays a zipf trace with the cache on vs off
(`capacity=0`, the documented pass-through arm) and shows the tier's
whole story:

* bitwise parity — identical NAG, per-request gain and policy state
  across the two arms (the cache changes *when* an answer is produced,
  never *what* it is);
* precise churn invalidation — removes drop exactly the entries that
  served the removed id, adds invalidate by a conservative radius
  check, and the parity still holds through the mutations;
* the online engine's arrival-time fast path — hits complete at
  `arrival + hit_ms` instead of queueing for a batch slot;
* idle unload — after an idle window the index's heavy device
  structures move to host memory, and hits keep serving while unloaded.

  PYTHONPATH=src python examples/answer_cache_tier.py
  PYTHONPATH=src python examples/answer_cache_tier.py --tiny
"""

import argparse

import numpy as np
import jax.numpy as jnp

from repro.core import CostModel, PolicySpec, build_policy
from repro.core.costs import calibrate_fetch_cost
from repro.core.trace import sift_like
from repro.index import IndexSpec
from repro.serve import AnswerCacheSpec, ArrivalSpec
from repro.serve.queue import (BatchFormerConfig, OnlineServingEngine,
                               ServiceModel)


def build(catalog, c_f, h, k, cap, index_spec=None, **spec_kw):
    return build_policy(
        PolicySpec("acai", {"h": h, "k": k, "batch": 8}), catalog,
        CostModel(c_f=c_f), index_spec=index_spec or IndexSpec("flat"),
        seed=0, answer_cache=AnswerCacheSpec(capacity=cap, **spec_kw))


def main(tiny: bool = False):
    n, t, h, k = (512, 256, 24, 4) if tiny else (4000, 4096, 150, 10)
    catalog, reqs, _ = sift_like(n=n, d=32, t=t, zipf_a=1.1, jitter=0.0,
                                 seed=17)
    c_f = float(calibrate_fetch_cost(jnp.asarray(catalog),
                                     kth=min(50, n - 1)))

    # -- cache on vs pass-through: same answers, scans skipped -------------
    arms = {}
    for cap in (4096, 0):
        pol = build(catalog, c_f, h, k, cap)
        res = pol.replay(reqs)
        arms[cap] = (pol, res)
    (pol_on, r_on), (pol_off, r_off) = arms[4096], arms[0]
    assert np.array_equal(r_on["gain"], r_off["gain"])
    assert np.array_equal(np.asarray(pol_on.cache.state.y),
                          np.asarray(pol_off.cache.state.y))
    st = pol_on.answer_cache.stats()
    nag = pol_on.normalized_gain(float(r_on["gain"].sum()),
                                 r_on["requests"])
    print(f"zipf trace n={n} t={t}: NAG={nag:.4f} (bitwise equal across "
          f"arms)")
    print(f"answer hit rate {st['hit_rate']:.3f}, "
          f"{st['scans_skipped']} of {st['scans'] + st['scans_skipped']} "
          f"scans skipped, {st['entries']} entries")
    print("(a scan is skipped only when ALL rows of a batch hit — the "
          "batch contract that makes parity bitwise)\n")

    # -- churn invalidation keeps parity -----------------------------------
    rng = np.random.default_rng(5)
    newv = rng.random((16, 32), dtype=np.float32)
    for cap in (4096, 0):
        pol, _ = arms[cap]
        pol.add_objects(newv)
        pol.serve_update_batch(reqs[:8])
    # remove an id the on-arm's store is serving; mirror it in the off arm
    doomed = next(iter(pol_on.answer_cache.cache._inv))
    for cap in (4096, 0):
        arms[cap][0].remove_objects([doomed])
        arms[cap][0].serve_update_batch(reqs[:8])
    assert np.array_equal(np.asarray(pol_on.cache.state.y),
                          np.asarray(pol_off.cache.state.y))
    st = pol_on.answer_cache.stats()
    print(f"after add+remove churn: invalidations={st['invalidations']} "
          f"(remove={st['inv_remove']}, add={st['inv_add']}), "
          f"parity still bitwise\n")

    # -- the engine fast path: hits answer at arrival ----------------------
    # (IVF here so the idle unload below has heavy structures — centroids,
    # inverted lists — to actually move off the device; flat has none)
    service = ServiceModel()
    ivf = IndexSpec("ivf", {"nlist": max(n // 40, 4), "nprobe": 8})
    pol = build(catalog, c_f, h, k, 4096, index_spec=ivf,
                hit_ms=0.2, idle_unload_ms=200.0)
    eng = OnlineServingEngine(
        pol, former=BatchFormerConfig(max_batch=8, max_wait_ms=5.0),
        service=service)
    res = eng.run(reqs, ArrivalSpec(kind="poisson",
                                    rate_rps=0.8 * service.capacity_rps(8),
                                    seed=11))
    print(f"online engine at 0.8 load: answer_hit_rate="
          f"{res['answer_hit_rate']:.3f}")
    print(f"  p50 user latency {res['p50_user_ms']:.3f}ms  "
          f"(hits {res['p50_hit_ms']:.3f}ms, misses "
          f"{res['p50_miss_ms']:.3f}ms — the fast path)\n")

    # -- idle unload: heavy structures leave the device, hits keep serving -
    ci = pol.answer_cache
    ci.tick(res["done_ms"].max() + 10_000.0)   # long idle
    hot = reqs[:8]
    ci.query(hot, pol.cache.cfg.c_remote)      # all-hit while unloaded
    st = ci.stats()
    print(f"idle unload: loaded={st['loaded']} after idle tick, "
          f"unloads={st['unloads']}, reloads={st['reloads']} "
          f"(hits served while unloaded; first miss reloads bitwise)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="seconds-fast sizes (CI smoke)")
    main(ap.parse_args().tiny)
