"""Quickstart: AÇAI similarity caching on a synthetic SIFT-like trace.

Builds a catalog through the TraceSpec registry, calibrates the fetching
cost the paper's way (average distance of the 50th neighbour), then
replays the same trace through every registered policy — AÇAI (exact and
over an IVF index selected by IndexSpec) and the classical baselines —
via the unified PolicySpec/build_policy API (DESIGN.md §8/§9), printing
the normalised average gain (Eq. 11): the paper's headline result
(Fig. 1) in miniature.

  PYTHONPATH=src python examples/quickstart.py          # ~a minute on CPU
  PYTHONPATH=src python examples/quickstart.py --tiny   # seconds (CI smoke)
"""

import argparse

import numpy as np
import jax.numpy as jnp

from repro.core import CostModel, PolicySpec, TraceSpec, build_policy, build_trace
from repro.core import baselines as B
from repro.core.costs import calibrate_fetch_cost
from repro.core.policy_api import replay_trace
from repro.index import IndexSpec


def main(tiny: bool = False):
    n, t, h, k = (400, 400, 24, 4) if tiny else (4000, 4000, 150, 10)
    tspec = TraceSpec("sift_like", {"n": n, "d": 32, "t": t, "seed": 0})
    catalog, requests, _ = build_trace(tspec)
    c_f = float(calibrate_fetch_cost(jnp.asarray(catalog),
                                     kth=min(50, n - 1)))
    print(f"trace {tspec.to_dict()}, cache h={h}, k={k}, c_f={c_f:.3f}\n")

    # one shared exact-kNN oracle per trace: every baseline reads it
    oracle = B.ServerOracle(catalog, requests, kmax=max(2 * k, 16))
    ts = np.arange(t)

    acai = PolicySpec("acai", {"h": h, "k": k, "batch": 8})
    tuned = {"h": h, "k": k, "k_prime": 2 * k, "c_theta": 1.5 * c_f}
    # (label, policy spec, index spec) — IndexSpec is the backend knob
    # (flat | ivf | ivfpq | lsh | nsw), exercised on the second AÇAI cell
    cells = [
        ("acai (exact)", acai, None),
        ("acai (ivf)", acai, IndexSpec("ivf", {"nlist": max(n // 60, 4),
                                               "nprobe": 8})),
        ("sim_lru", PolicySpec("sim_lru", tuned), None),
        ("cls_lru", PolicySpec("cls_lru", tuned), None),
        ("lru", PolicySpec("lru", {"h": h, "k": k}), None),
        ("qcache", PolicySpec("qcache", {"h": h, "k": k}), None),
    ]

    curves = {}
    for label, spec, ispec in cells:
        pol = build_policy(spec, catalog, CostModel(c_f=c_f), oracle=oracle,
                           index_spec=ispec, seed=0)
        res = replay_trace(pol, requests, ts, batch=8)
        curves[label] = B.nag(res["gain"], pol.k, pol.c_f)
        print(f"{label:14s} NAG={curves[label][-1]:.4f}  "
              f"(hit ratio {res['hit'].mean():.3f}, "
              f"p50 step {res['p50_step_s'] * 1e6:.0f}us)")

    marks = [i for i in (99, 499, 999, 1999, t - 1) if i < t]
    print("\nNAG trajectory (acai exact):",
          " ".join(f"{curves['acai (exact)'][i]:.3f}" for i in marks))


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="seconds-fast sizes (CI smoke)")
    args = ap.parse_args()
    main(args.tiny)
