"""Pallas kernels: shape/dtype sweeps, interpret-mode vs the jnp oracles."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("q,n,d", [(4, 100, 16), (128, 256, 128), (37, 513, 64),
                                   (1, 2000, 32), (130, 129, 48)])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_pairwise_l2(q, n, d, dtype):
    rng = np.random.default_rng(0)
    qa = jnp.array(rng.normal(size=(q, d)).astype(dtype))
    xa = jnp.array(rng.normal(size=(n, d)).astype(dtype))
    got = np.array(ops.pairwise_l2(qa, xa))
    want = np.array(ref.pairwise_l2_ref(qa, xa))
    tol = 1e-4 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(got, want, rtol=tol * 10, atol=tol)


@pytest.mark.parametrize("q,n,d,k", [(4, 100, 16, 1), (64, 400, 32, 8),
                                     (9, 300, 24, 16), (1, 150, 8, 5)])
def test_topk_l2(q, n, d, k):
    rng = np.random.default_rng(1)
    qa = jnp.array(rng.normal(size=(q, d)).astype(np.float32))
    xa = jnp.array(rng.normal(size=(n, d)).astype(np.float32))
    gd, gi = ops.topk_l2(qa, xa, k)
    wd, wi = ref.l2_topk_ref(qa, xa, k)
    np.testing.assert_allclose(np.array(gd), np.array(wd), rtol=1e-4, atol=1e-4)
    # ids may differ under distance ties: check distances of returned ids
    d_of_ids = np.array(ref.pairwise_l2_ref(qa, xa))[
        np.arange(q)[:, None], np.array(gi)
    ]
    np.testing.assert_allclose(d_of_ids, np.array(wd), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("q,n,m,c", [(2, 64, 4, 16), (128, 300, 8, 256),
                                     (5, 1000, 16, 256), (1, 50, 2, 4)])
def test_pq_adc(q, n, m, c):
    rng = np.random.default_rng(2)
    lut = jnp.array(rng.random((q, m, c)).astype(np.float32))
    codes = jnp.array(rng.integers(0, c, (n, m)).astype(np.int32))
    got = np.array(ops.pq_adc(lut, codes))
    want = np.array(ref.pq_adc_ref(lut, codes))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("b,n,p,d,k", [(4, 200, 64, 16, 8), (5, 300, 37, 16, 8),
                                       (12, 500, 130, 32, 16), (1, 100, 9, 8, 4)])
def test_ivf_scan(b, n, p, d, k):
    """Fused gather+L2+top-k (interpret mode) vs the XLA oracle, with
    padded lists and -1 sentinels."""
    rng = np.random.default_rng(4)
    x = jnp.array(rng.normal(size=(n, d)).astype(np.float32))
    q = jnp.array(rng.normal(size=(b, d)).astype(np.float32))
    cand = rng.integers(0, n, (b, p)).astype(np.int32)
    cand[rng.random((b, p)) < 0.3] = -1  # inverted-list padding
    cand = jnp.array(cand)
    gd, gi = ops.ivf_scan_topk(q, x, cand, k, interpret=True)
    wd, wi = ref.ivf_scan_ref(q, x, cand, k)
    np.testing.assert_allclose(np.array(gd), np.array(wd), rtol=1e-4, atol=1e-4)
    # ids may differ under ties: distances of the returned ids must agree,
    # and underflow sentinels must land in the same slots
    got_i, want_i = np.array(gi), np.array(wi)
    np.testing.assert_array_equal(got_i == -1, want_i == -1)
    dmat = np.array(ref.pairwise_l2_ref(q, x))
    sel = got_i >= 0
    np.testing.assert_allclose(
        dmat[np.nonzero(sel)[0], got_i[sel]], np.array(wd)[sel],
        rtol=1e-4, atol=1e-4,
    )


def test_ivf_scan_k_underflow():
    """Queries with fewer than k valid candidates surface -1 ids, +inf."""
    rng = np.random.default_rng(5)
    x = jnp.array(rng.normal(size=(50, 8)).astype(np.float32))
    q = jnp.array(rng.normal(size=(3, 8)).astype(np.float32))
    cand = np.full((3, 20), -1, np.int32)
    cand[0, :2] = [7, 31]          # 2 valid < k
    cand[1, :] = -1                # no valid candidates at all
    cand[2, :6] = [1, 1, 2, 3, 4, 5]  # duplicates allowed, 6 slots
    gd, gi = ops.ivf_scan_topk(q, x, jnp.array(cand), 5, interpret=True)
    wd, wi = ref.ivf_scan_ref(q, x, jnp.array(cand), 5)
    np.testing.assert_allclose(np.array(gd), np.array(wd), rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.array(gi) == -1, np.array(wi) == -1)
    assert (np.array(gi)[1] == -1).all()
    assert np.isinf(np.array(gd)[0, 2:]).all()


def test_l2_nonnegative_and_zero_diagonal():
    rng = np.random.default_rng(3)
    x = jnp.array(rng.normal(size=(64, 32)).astype(np.float32))
    d = np.array(ops.pairwise_l2(x, x))
    assert (d >= 0).all()
    np.testing.assert_allclose(np.diag(d), 0.0, atol=1e-3)


@pytest.mark.parametrize("b,s,t,h,kv,d,causal,window",
                         [(2, 64, 64, 4, 2, 32, True, 0),
                          (1, 128, 128, 8, 8, 64, True, 0),
                          (2, 64, 64, 4, 4, 32, False, 0),
                          (2, 64, 64, 4, 2, 32, True, 24),
                          (1, 32, 128, 4, 2, 32, True, 0)])
def test_flash_attention_kernel(b, s, t, h, kv, d, causal, window):
    """Pallas flash-attention vs the dense attention_core oracle."""
    import dataclasses
    from repro.configs import SMOKE_ARCHS
    from repro.models import layers as L

    rng = np.random.default_rng(0)
    q = jnp.array(rng.normal(size=(b, s, h, d)).astype(np.float32))
    k = jnp.array(rng.normal(size=(b, t, kv, d)).astype(np.float32))
    v = jnp.array(rng.normal(size=(b, t, kv, d)).astype(np.float32))
    cfg = dataclasses.replace(SMOKE_ARCHS["minitron-8b"], causal=causal,
                              sliding_window=window)
    want = L.attention_core(q, k, v, t - s, cfg, written_upto=t)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              q_offset=t - s, written_upto=t)
    np.testing.assert_allclose(np.array(got), np.array(want),
                               rtol=1e-4, atol=1e-4)
