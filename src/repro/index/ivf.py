"""IVF-Flat index: coarse k-means partition + exact scan of probed lists.

JAX/TPU adaptation of the FAISS inverted-file layout: inverted lists are a
dense (nlist, cap) id table padded with -1, so probing is a static gather —
no pointer chasing, shapes jit/shard cleanly (the table shards row-wise over
the `model` mesh axis at scale).

Mutable catalog (DESIGN.md §10): `add` assigns new rows to their nearest
*existing* centroid and appends to that inverted list (per-table capacity
doubling when a list fills); `remove` tombstones rows — stale list entries
are folded into the scan's -1 invalid-slot convention at query time via the
validity mask; `refresh` re-trains the coarse quantizer and rebuilds the
lists over the live rows only (row ids stay stable).  The quantizer drifts
between refreshes (new objects are binned by stale centroids), which is
exactly the recall-vs-refresh-cost trade-off the churn bench measures.

Sharded serving (DESIGN.md §15): `ivf_sharded` splits the slab row-wise
over the mesh's `model` axis and each shard scans its own probed lists
inside the fused sharded step.  That sharded *structure* is still
immutable — online mutation on a mesh serves through the exact masked
scan instead (`AcaiCache(mesh=...)` with `index=None`); teaching the
sharded inverted lists to accept owner-routed appends is the remaining
ROADMAP item, not a driver or policy limitation.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.index.base import (MutableRows, _flat_set, arrays_bytes,
                              check_finite_queries, pad_ids, pad_rows,
                              run_device, track_jit)
from repro.index.kmeans import kmeans
from repro.kernels import ops


def build_invlists(assign: np.ndarray, nlist: int, cap: int | None = None):
    """Dense padded inverted lists from an assignment vector."""
    counts = np.bincount(assign, minlength=nlist)
    cap = int(counts.max()) if cap is None else cap
    table = np.full((nlist, cap), -1, np.int32)
    cursor = np.zeros(nlist, np.int32)
    for i, a in enumerate(assign):
        c = cursor[a]
        if c < cap:
            table[a, c] = i
            cursor[a] = c + 1
    return table


def invlist_positions(cursor: np.ndarray, assign: np.ndarray) -> np.ndarray:
    """Destination column of each appended id in its inverted list (the
    cursor plus the id's rank among same-list ids earlier in the batch).
    Host-side bookkeeping only — the actual write is a donated device
    scatter.  Advances `cursor` in place."""
    pos = np.empty(assign.shape[0], np.int32)
    for j, a in enumerate(assign):
        pos[j] = cursor[a]
        cursor[a] += 1
    return pos


def invlist_device_append(invlists: jax.Array, cursor: np.ndarray,
                          assign: np.ndarray, ids: np.ndarray) -> jax.Array:
    """Append `ids` to their assigned lists in the device-resident
    (nlist, cols) table: host cursor bookkeeping plus one donated flat
    scatter (padded lanes carry an out-of-range flat index and are
    dropped).  A full list doubles the table column-wise — a rare
    reallocation, warmed away like slab growth.  Returns the new table;
    `cursor` is advanced in place."""
    counts = np.bincount(assign, minlength=cursor.shape[0])
    need = int((cursor + counts).max())
    cols = invlists.shape[1]
    if need > cols:
        cols = max(2 * cols, need)
        invlists = jnp.pad(invlists,
                           ((0, 0), (0, cols - invlists.shape[1])),
                           constant_values=-1)
    pos = invlist_positions(cursor, assign)
    oob = invlists.size
    assert oob < np.iinfo(np.int32).max, "invlist tensor exceeds int32"
    flat = (assign.astype(np.int64) * cols + pos).astype(np.int32)
    return run_device(_flat_set, invlists, pad_ids(flat, oob),
                      pad_ids(ids, -1))


@track_jit("ivf_assign")
@jax.jit
def _assign_lists(vecs: jax.Array, centroids: jax.Array) -> jax.Array:
    """Nearest existing centroid per (padded) incoming row."""
    return jnp.argmin(ops.pairwise_l2_xla(vecs, centroids),
                      axis=1).astype(jnp.int32)


@track_jit("ivf_query")
@partial(jax.jit, static_argnames=("k", "nprobe", "masked"))
def _ivf_query(q, emb, centroids, invlists, valid, k: int, nprobe: int,
               masked: bool):
    """(B, d) -> (dists (B, k), ids (B, k)); ids = -1 on underflow.

    The probed lists go through the fused gather+L2+top-k scan
    (repro.kernels.ivf_scan on TPU, its XLA oracle elsewhere), so the
    (B, P, d) gathered embeddings never materialise in HBM.  `masked`
    threads the tombstone mask through the scan (fresh builds skip it and
    stay bitwise identical to the static-catalog path)."""
    q = jnp.atleast_2d(q)
    dc = ops.pairwise_l2_xla(q, centroids)              # (B, nlist)
    _, probe = jax.lax.top_k(-dc, nprobe)                # (B, nprobe)
    cand = invlists[probe].reshape(q.shape[0], -1)       # (B, nprobe*cap)
    return ops.ivf_scan_auto(q, emb, cand, k, valid if masked else None)


class IVFFlatIndex(MutableRows):
    exact_distances = True  # probed lists are scanned with exact L2

    def __init__(
        self,
        embeddings,
        nlist: int = 64,
        nprobe: int = 8,
        train_iters: int = 12,
        seed: int = 0,
    ):
        self._init_rows(embeddings)
        self.nlist, self.nprobe = nlist, nprobe
        self.train_iters, self.seed = train_iters, seed
        self._build_structures()

    # -- structure (re)build ------------------------------------------------

    def _compute_structures(self):
        """(Re-)train the coarse quantizer and lists over the live rows.

        Row ids are stable: the k-means/table build runs over the live
        rows in slab order and the resulting local ids are remapped back
        to slab ids, so a refreshed index answers exactly like a fresh
        build on the live rows (modulo that id remap).  Pure — the live
        structures keep serving until `_install_structures` swaps the new
        bundle in (the double-buffered refresh of DESIGN.md §14)."""
        live = self.live_rows()
        n_live = len(live)
        emb_live = (self.embeddings if n_live == self.capacity
                    else self.embeddings[jnp.asarray(live)])
        nlist = min(self.nlist, max(n_live, 1))
        key = jax.random.PRNGKey(self.seed)
        centroids, assign = kmeans(key, emb_live, nlist, self.train_iters)
        table = build_invlists(np.asarray(assign), nlist)
        if n_live != self.capacity:  # remap local ids -> slab row ids
            table = np.where(table >= 0, live[np.clip(table, 0, None)], -1)
        cursor = (table >= 0).sum(axis=1).astype(np.int32)
        return (centroids, jnp.asarray(table, jnp.int32), cursor)

    def _install_structures(self, structures) -> None:
        self.centroids, self.invlists, self._cursor = structures

    # -- mutation -----------------------------------------------------------

    def add(self, vectors) -> np.ndarray:
        """Append rows and bin them by the *current* (possibly stale)
        coarse quantizer — FAISS's add-time behaviour.

        Device-resident fast path: assignment runs as a tracked jit on the
        width-padded incoming batch, destination columns are host cursor
        bookkeeping, and the ids land in the (nlist, cols) table via one
        donated flat scatter — no numpy table master, no full re-upload.
        A full list still doubles the table column-wise (a rare
        reallocation, warmed away like slab growth)."""
        vec_np = np.asarray(vectors, np.float32)
        ids = self._append_rows(vec_np)
        b = ids.shape[0]
        assign = np.asarray(run_device(
            _assign_lists, pad_rows(vec_np), self.centroids))[:b]
        self.invlists = invlist_device_append(self.invlists, self._cursor,
                                              assign, ids)
        return ids

    # -- queries ------------------------------------------------------------

    def memory_bytes(self) -> int:
        return arrays_bytes(self.embeddings, self.centroids, self.invlists,
                            self.valid)

    def query(self, q: jax.Array, k: int):
        check_finite_queries(q, "IVFFlatIndex.query")
        # candidates come from the id tables (never from unused slab rows),
        # so the mask is only needed once a row has been tombstoned
        return _ivf_query(q, self.embeddings, self.centroids, self.invlists,
                          self.valid, k,
                          min(self.nprobe, self.centroids.shape[0]),
                          masked=self._live != self._n_slots)

    def __hash__(self):
        return id(self)

    def __eq__(self, other):
        return self is other
