"""Roofline analysis over the dry-run artifacts (deliverable g).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun), derives
the three roofline terms per (arch x shape x mesh) using the TPU v5e
constants, identifies the dominant bottleneck, and emits the §Roofline
table (markdown + CSV).

  compute    = HLO_FLOPs_per_device / peak_FLOPs
  memory     = HLO_HBM_bytes_per_device / HBM_bw
  collective = collective_bytes_per_shard / link_bw
  MODEL_FLOPS (global) = 6 N_active D (train) | 2 N_active D (prefill)
                         | 2 N_active B (decode, per emitted token)
  roofline_fraction = [MODEL_FLOPS / (chips * peak)] / max(terms)
"""

from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12     # bf16 / chip (TPU v5e)
HBM_BW = 819e9          # B/s / chip
LINK_BW = 50e9          # B/s / link (ICI)


def model_flops(rec: dict) -> float:
    n = rec["params_active"]
    b, s = rec["global_batch"], rec["seq_len"]
    if rec["kind"] == "train":
        return 6.0 * n * b * s
    if rec["kind"] == "prefill":
        return 2.0 * n * b * s
    return 2.0 * n * b  # decode: one token per sequence


def derive(rec: dict) -> dict | None:
    if rec.get("status") != "ok" or "hlo" not in rec:
        return None
    h = rec["hlo"]
    chips = rec["n_devices"]
    compute = h["flops_per_device"] / PEAK_FLOPS
    memory = h["hbm_bytes_per_device"] / HBM_BW
    coll = sum(h["collective_bytes_per_shard"].values()) / LINK_BW
    terms = {"compute": compute, "memory": memory, "collective": coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec)
    ideal = mf / (chips * PEAK_FLOPS)
    bound = max(terms.values())
    hlo_global = h["flops_per_device"] * chips
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec["kind"],
        "compute_s": compute, "memory_s": memory, "collective_s": coll,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        "roofline_fraction": ideal / bound if bound else 0.0,
        "mem_gb_per_dev": (rec.get("params_bytes_per_device", 0)
                           + rec.get("opt_bytes_per_device", 0)
                           + rec.get("cache_bytes_per_device", 0)) / 2**30,
        "collective_counts": h.get("collective_counts", {}),
        "coll_by_class": h.get("collective_bytes_per_shard", {}),
    }


def suggestion(row: dict) -> str:
    if row["dominant"] == "memory":
        if row["kind"] == "train":
            return ("fuse attention/softmax traffic (flash path), cut remat "
                    "re-reads")
        return "shrink cache dtype / fuse decode gathers"
    if row["dominant"] == "collective":
        return ("overlap grad all-reduce with backward; shard/reschedule "
                "the dominant collective class")
    if row["useful_ratio"] < 0.5:
        return "reduce remat recompute + non-model flops (attention/dispatch)"
    return "increase arithmetic intensity (larger per-chip tiles)"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single",
                    help="mesh for the table (single|multi|both)")
    ap.add_argument("--csv", default="")
    args = ap.parse_args()

    rows, skips, fails = [], [], []
    for path in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        rec = json.load(open(path))
        if rec.get("status") == "skipped":
            skips.append(rec)
            continue
        if rec.get("status") != "ok":
            fails.append(rec)
            continue
        row = derive(rec)
        if row and (args.mesh == "both" or row["mesh"] == args.mesh):
            rows.append(row)

    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    hdr = (f"| {'arch':24s} | {'shape':11s} | {'mesh':6s} | compute(s) | "
           f"memory(s) | collect(s) | dominant   | 6ND/HLO | roofline |")
    print(hdr)
    print("|" + "-" * (len(hdr) - 2) + "|")
    for r in rows:
        print(f"| {r['arch']:24s} | {r['shape']:11s} | {r['mesh']:6s} "
              f"| {r['compute_s']:10.4f} | {r['memory_s']:9.4f} "
              f"| {r['collective_s']:10.4f} | {r['dominant']:10s} "
              f"| {r['useful_ratio']:7.3f} | {r['roofline_fraction']:8.3f} |")
    print(f"\n{len(rows)} cells ok, {len(skips)} skipped, "
          f"{len(fails)} failed")
    for rec in skips:
        print(f"  skip: {rec['arch']} {rec['shape']} {rec['mesh']}: "
              f"{rec['reason']}")
    for rec in fails:
        print(f"  FAIL: {rec['arch']} {rec['shape']} {rec['mesh']}: "
              f"{rec.get('error', '?')[:120]}")

    if args.csv:
        import csv as _csv
        with open(args.csv, "w", newline="") as f:
            w = _csv.DictWriter(f, fieldnames=[k for k in rows[0]
                                               if k not in (
                                                   "collective_counts",
                                                   "coll_by_class")])
            w.writeheader()
            for r in rows:
                w.writerow({k: v for k, v in r.items()
                            if k not in ("collective_counts",
                                         "coll_by_class")})
        print("wrote", args.csv)


if __name__ == "__main__":
    main()
