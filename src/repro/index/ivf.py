"""IVF-Flat index: coarse k-means partition + exact scan of probed lists.

JAX/TPU adaptation of the FAISS inverted-file layout: inverted lists are a
dense (nlist, cap) id table padded with -1, so probing is a static gather —
no pointer chasing, shapes jit/shard cleanly (the table shards row-wise over
the `model` mesh axis at scale).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.index.base import arrays_bytes
from repro.index.kmeans import kmeans
from repro.kernels import ops


def build_invlists(assign: np.ndarray, nlist: int, cap: int | None = None):
    """Dense padded inverted lists from an assignment vector."""
    counts = np.bincount(assign, minlength=nlist)
    cap = int(counts.max()) if cap is None else cap
    table = np.full((nlist, cap), -1, np.int32)
    cursor = np.zeros(nlist, np.int32)
    for i, a in enumerate(assign):
        c = cursor[a]
        if c < cap:
            table[a, c] = i
            cursor[a] = c + 1
    return table


class IVFFlatIndex:
    exact_distances = True  # probed lists are scanned with exact L2

    def __init__(
        self,
        embeddings,
        nlist: int = 64,
        nprobe: int = 8,
        train_iters: int = 12,
        seed: int = 0,
    ):
        self.embeddings = jnp.asarray(embeddings, jnp.float32)
        self.nlist, self.nprobe = nlist, nprobe
        key = jax.random.PRNGKey(seed)
        self.centroids, assign = kmeans(key, self.embeddings, nlist, train_iters)
        self.invlists = jnp.asarray(
            build_invlists(np.asarray(assign), nlist), jnp.int32
        )

    @property
    def n(self) -> int:
        return self.embeddings.shape[0]

    def memory_bytes(self) -> int:
        return arrays_bytes(self.embeddings, self.centroids, self.invlists)

    @partial(jax.jit, static_argnames=("self", "k"))
    def query(self, q: jax.Array, k: int):
        """(B, d) -> (dists (B, k), ids (B, k)); ids = -1 on underflow.

        The probed lists go through the fused gather+L2+top-k scan
        (repro.kernels.ivf_scan on TPU, its XLA oracle elsewhere), so the
        (B, P, d) gathered embeddings never materialise in HBM."""
        q = jnp.atleast_2d(q)
        dc = ops.pairwise_l2_xla(q, self.centroids)        # (B, nlist)
        _, probe = jax.lax.top_k(-dc, self.nprobe)          # (B, nprobe)
        cand = self.invlists[probe].reshape(q.shape[0], -1)  # (B, nprobe*cap)
        return ops.ivf_scan_auto(q, self.embeddings, cand, k)

    def __hash__(self):
        return id(self)

    def __eq__(self, other):
        return self is other
